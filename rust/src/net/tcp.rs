//! TCP transport of the socket front-end: one connection is one
//! streaming [`Session`](crate::coordinator::Session).
//!
//! Server side: an accept-loop thread spawns one thread per connection
//! (`std::net` blocking I/O — the pipeline's bounded channels provide
//! the backpressure). The connection thread reads frames; a small
//! writer thread drains the session's in-order decoded output to BITS
//! frames, so decoding overlaps with the client still pushing DATA.
//! Idle eviction rides the socket read timeout: a connection that
//! stays silent for the configured idle timeout is evicted (counted in
//! `net.sessions_evicted`) and closed.
//!
//! Every connection path — clean END, dirty disconnect, protocol
//! error, idle eviction — closes the pipeline session exactly once
//! (`SessionHandle::finish`), so the reassembler never leaks session
//! state and `Coordinator::shutdown` never hangs on an abandoned
//! session.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::DecoderBuilder;
use crate::coordinator::SessionHandle;
use crate::defaults;
use crate::error::{Error, Result, ResultExt};

use super::protocol::{
    decode_llrs, decode_reject, encode_llrs, encode_reject, frame_wire_bytes, kind, read_frame,
    reject, reject_reason_name, write_frame, Ack, Hello, ReadOutcome,
};
use super::{Contract, ServerCtx};

/// How long a client waits for a server frame before giving up.
const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Write one frame under the shared writer lock and count its wire
/// bytes.
fn send(ctx: &ServerCtx, w: &Mutex<TcpStream>, frame_kind: u8, payload: &[u8]) -> Result<()> {
    let mut g = w.lock().unwrap();
    write_frame(&mut *g, frame_kind, payload)?;
    ctx.metrics.net.bytes_out.fetch_add(frame_wire_bytes(payload.len()), Ordering::Relaxed);
    Ok(())
}

fn send_error(ctx: &ServerCtx, w: &Mutex<TcpStream>, e: &Error) {
    let _ = send(ctx, w, kind::ERROR, e.to_string().as_bytes());
}

fn send_metrics(ctx: &ServerCtx, w: &Mutex<TcpStream>) {
    let snap = ctx.metrics.snapshot().to_json().to_string_pretty();
    let _ = send(ctx, w, kind::METRICS, snap.as_bytes());
}

/// Accept loop (one per server). Exits when the shutdown flag is set;
/// `Server::shutdown` unblocks it with a dummy self-connection.
pub(crate) fn run_acceptor(listener: TcpListener, ctx: Arc<ServerCtx>) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if ctx.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                ctx.conns.fetch_add(1, Ordering::SeqCst);
                let ctx2 = ctx.clone();
                let spawned = std::thread::Builder::new().name("tcvd-net-conn".into()).spawn(
                    move || {
                        handle_conn(stream, &ctx2);
                        ctx2.conns.fetch_sub(1, Ordering::SeqCst);
                    },
                );
                if spawned.is_err() {
                    ctx.conns.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(_) => {
                if ctx.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // transient accept failure: keep serving
            }
        }
    }
}

/// Outcome of the post-handshake session loop.
enum Outcome {
    /// FINISH processed; the instant it was received (latency clock).
    Clean(Instant),
    /// Dirty disconnect, idle timeout, or protocol/pipeline error.
    Dirty,
}

fn handle_conn(stream: TcpStream, ctx: &Arc<ServerCtx>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(ctx.table.idle_timeout()));
    let writer = match stream.try_clone() {
        Ok(c) => Arc::new(Mutex::new(c)),
        Err(_) => return,
    };
    let mut reader = stream;

    // ---- handshake: METRICS_REQ is answered sessionless; a HELLO
    // opens the session ----
    let hello = loop {
        match read_frame(&mut reader, ctx.net.max_frame_bytes) {
            Ok(ReadOutcome::Frame(k, p)) => {
                ctx.metrics.net.bytes_in.fetch_add(frame_wire_bytes(p.len()), Ordering::Relaxed);
                match k {
                    kind::METRICS_REQ => send_metrics(ctx, &writer),
                    kind::HELLO => match Hello::decode(&p) {
                        Ok(h) => break h,
                        Err(e) => {
                            send_error(ctx, &writer, &e);
                            return;
                        }
                    },
                    other => {
                        send_error(
                            ctx,
                            &writer,
                            &Error::net(format!("expected HELLO, got frame kind {other:#04x}")),
                        );
                        return;
                    }
                }
            }
            // silence or disconnect before a session existed: nothing
            // to evict, nothing to count
            Ok(ReadOutcome::Eof) | Ok(ReadOutcome::TimedOut) | Err(_) => return,
        }
    };

    if let Err(e) = ctx.contract.check_hello(&hello) {
        ctx.metrics.net.handshake_rejects.fetch_add(1, Ordering::Relaxed);
        let _ = send(ctx, &writer, kind::REJECT, &encode_reject(reject::CONFIG, e.message()));
        return;
    }
    // admission: the saturation signal is checked before the cap so a
    // saturated server sheds deterministically even with free slots
    if ctx.queues_saturated() {
        ctx.metrics.net.sessions_shed.fetch_add(1, Ordering::Relaxed);
        let detail = format!("shard queues at depth {}", ctx.metrics.queue_depth_total());
        let _ = send(ctx, &writer, kind::REJECT, &encode_reject(reject::QUEUE_SATURATED, &detail));
        return;
    }
    if !ctx.table.admit_tcp() {
        ctx.metrics.net.sessions_shed.fetch_add(1, Ordering::Relaxed);
        let detail = format!("session cap {} reached", ctx.net.max_sessions);
        let _ = send(ctx, &writer, kind::REJECT, &encode_reject(reject::SESSION_CAP, &detail));
        return;
    }

    let session = match ctx.coord.open_session() {
        Ok(s) => s,
        Err(e) => {
            ctx.table.release_tcp();
            send_error(ctx, &writer, &e);
            return;
        }
    };
    ctx.metrics.net.sessions_accepted.fetch_add(1, Ordering::Relaxed);
    let ack = Ack {
        session: session.id(),
        frame_stages: ctx.coord.tile().frame_stages() as u32,
        beta: ctx.coord.trellis().code().beta() as u32,
    };
    let (mut handle, rx) = session.split();

    // writer thread: drain the in-order decoded output to BITS frames.
    // It always drains rx to exhaustion — even when the peer is gone —
    // so the reassembler is never blocked on a dead connection.
    let wctx = ctx.clone();
    let wsock = writer.clone();
    let writer_thread = std::thread::spawn(move || {
        for chunk in rx {
            let n = chunk.len();
            let ok = {
                let mut g = wsock.lock().unwrap();
                write_frame(&mut *g, kind::BITS, &chunk).is_ok()
            };
            if ok {
                wctx.metrics.net.bytes_out.fetch_add(frame_wire_bytes(n), Ordering::Relaxed);
            }
        }
    });

    let outcome = if send(ctx, &writer, kind::ACK, &ack.encode()).is_ok() {
        run_session(&mut reader, ctx, &writer, &mut handle)
    } else {
        Outcome::Dirty
    };
    // the dirty paths have not closed the session yet: do it now (a
    // second finish on an already-closed handle is a harmless typed
    // error) so rx disconnects and the writer thread can exit
    if matches!(outcome, Outcome::Dirty) {
        let _ = handle.finish();
    }
    let _ = writer_thread.join();
    match outcome {
        Outcome::Clean(t_finish) => {
            ctx.metrics.record_net_block(t_finish.elapsed());
            let _ = send(ctx, &writer, kind::END, &[]);
        }
        Outcome::Dirty => {
            ctx.metrics.net.sessions_evicted.fetch_add(1, Ordering::Relaxed);
        }
    }
    ctx.table.release_tcp();
}

/// Post-ACK frame loop: DATA pushes, FINISH completes, METRICS_REQ
/// snapshots. Never calls `finish` on a dirty exit — the caller owns
/// the close-exactly-once discipline.
fn run_session(
    reader: &mut TcpStream,
    ctx: &ServerCtx,
    writer: &Mutex<TcpStream>,
    handle: &mut SessionHandle,
) -> Outcome {
    loop {
        match read_frame(reader, ctx.net.max_frame_bytes) {
            Ok(ReadOutcome::Frame(k, p)) => {
                ctx.metrics.net.bytes_in.fetch_add(frame_wire_bytes(p.len()), Ordering::Relaxed);
                match k {
                    kind::DATA => {
                        if let Err(e) = decode_llrs(&p).and_then(|llr| handle.push(&llr)) {
                            send_error(ctx, writer, &e);
                            return Outcome::Dirty;
                        }
                    }
                    kind::FINISH => {
                        let t_finish = Instant::now();
                        match handle.finish() {
                            Ok(()) => return Outcome::Clean(t_finish),
                            Err(e) => {
                                // the framer rejected the stream shape
                                // (e.g. a partial tail-biting tile);
                                // finish() already closed the session
                                send_error(ctx, writer, &e);
                                return Outcome::Dirty;
                            }
                        }
                    }
                    kind::METRICS_REQ => send_metrics(ctx, writer),
                    other => {
                        send_error(
                            ctx,
                            writer,
                            &Error::net(format!("unexpected frame kind {other:#04x} in session")),
                        );
                        return Outcome::Dirty;
                    }
                }
            }
            Ok(ReadOutcome::Eof) => return Outcome::Dirty,
            Ok(ReadOutcome::TimedOut) => {
                send_error(
                    ctx,
                    writer,
                    &Error::net(format!(
                        "session evicted: idle for {:?}",
                        ctx.table.idle_timeout()
                    )),
                );
                return Outcome::Dirty;
            }
            Err(e) => {
                send_error(ctx, writer, &e);
                return Outcome::Dirty;
            }
        }
    }
}

/// A connected TCP decode session. `connect` performs the HELLO/ACK
/// handshake from the builder's parameters; [`push`](TcpClient::push)
/// streams LLR chunks; [`finish`](TcpClient::finish) flushes the
/// stream and collects every decoded payload bit.
pub struct TcpClient {
    stream: TcpStream,
    ack: Ack,
}

impl TcpClient {
    /// Connect and handshake. The HELLO carries the builder's
    /// code/backend/termination/tile; a server running anything else
    /// rejects the session (the reject reason and detail land in the
    /// returned [`Error::Net`]).
    pub fn connect(addr: impl ToSocketAddrs, builder: &DecoderBuilder) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).or_net("connecting to tcvd server")?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT)).or_net("setting read timeout")?;
        let hello = Contract::of_builder(builder).hello().encode()?;
        write_frame(&mut (&stream), kind::HELLO, &hello)?;
        match read_frame(&mut (&stream), defaults::NET_MAX_FRAME_BYTES)? {
            ReadOutcome::Frame(kind::ACK, p) => {
                Ok(TcpClient { ack: Ack::decode(&p)?, stream })
            }
            ReadOutcome::Frame(kind::REJECT, p) => {
                let (reason, detail) = decode_reject(&p)?;
                Err(Error::net(format!(
                    "session rejected ({}): {detail}",
                    reject_reason_name(reason)
                )))
            }
            ReadOutcome::Frame(kind::ERROR, p) => {
                Err(Error::net(format!("server error: {}", String::from_utf8_lossy(&p))))
            }
            ReadOutcome::Frame(k, _) => {
                Err(Error::net(format!("unexpected frame kind {k:#04x} in handshake")))
            }
            ReadOutcome::Eof => Err(Error::net("server closed the connection during handshake")),
            ReadOutcome::TimedOut => Err(Error::net("timed out waiting for the handshake reply")),
        }
    }

    /// The server's ACK: session id + frame geometry.
    pub fn ack(&self) -> Ack {
        self.ack
    }

    /// Stream one LLR chunk (length must be a multiple of beta, like
    /// [`Session::push`](crate::coordinator::Session::push)).
    pub fn push(&mut self, llr: &[f32]) -> Result<()> {
        write_frame(&mut (&self.stream), kind::DATA, &encode_llrs(llr))
    }

    /// End the stream and collect every decoded payload bit (one byte
    /// per bit, in order). Consumes the client; the server closes the
    /// connection after its END frame.
    pub fn finish(self) -> Result<Vec<u8>> {
        write_frame(&mut (&self.stream), kind::FINISH, &[])?;
        let mut bits = Vec::new();
        loop {
            match read_frame(&mut (&self.stream), defaults::NET_MAX_FRAME_BYTES)? {
                ReadOutcome::Frame(kind::BITS, p) => bits.extend_from_slice(&p),
                ReadOutcome::Frame(kind::END, _) => return Ok(bits),
                ReadOutcome::Frame(kind::ERROR, p) => {
                    return Err(Error::net(format!(
                        "server error: {}",
                        String::from_utf8_lossy(&p)
                    )))
                }
                ReadOutcome::Frame(k, _) => {
                    return Err(Error::net(format!("unexpected frame kind {k:#04x} in stream")))
                }
                ReadOutcome::Eof => {
                    return Err(Error::net("connection closed before the END frame"))
                }
                ReadOutcome::TimedOut => {
                    return Err(Error::net("timed out waiting for decoded bits"))
                }
            }
        }
    }

    /// Fetch a metrics snapshot over this session's connection.
    pub fn metrics_json(&mut self) -> Result<String> {
        write_frame(&mut (&self.stream), kind::METRICS_REQ, &[])?;
        loop {
            match read_frame(&mut (&self.stream), defaults::NET_MAX_FRAME_BYTES)? {
                // in-flight decoded bits may interleave ahead of the
                // metrics reply: losing them would corrupt the stream,
                // so metrics_json is only valid before the first push
                // or after finish on a fresh connection
                ReadOutcome::Frame(kind::METRICS, p) => {
                    return String::from_utf8(p).or_net("metrics reply is not UTF-8")
                }
                ReadOutcome::Frame(kind::ERROR, p) => {
                    return Err(Error::net(format!(
                        "server error: {}",
                        String::from_utf8_lossy(&p)
                    )))
                }
                ReadOutcome::Frame(k, _) => {
                    return Err(Error::net(format!(
                        "unexpected frame kind {k:#04x} awaiting metrics"
                    )))
                }
                ReadOutcome::Eof => return Err(Error::net("connection closed awaiting metrics")),
                ReadOutcome::TimedOut => return Err(Error::net("timed out awaiting metrics")),
            }
        }
    }
}

/// One-shot metrics fetch: connect, METRICS_REQ, parse nothing — the
/// raw JSON text is returned (the `tcvd metrics` peer command).
pub fn fetch_metrics(addr: impl ToSocketAddrs) -> Result<String> {
    let stream = TcpStream::connect(addr).or_net("connecting to tcvd server")?;
    stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT)).or_net("setting read timeout")?;
    write_frame(&mut (&stream), kind::METRICS_REQ, &[])?;
    match read_frame(&mut (&stream), defaults::NET_MAX_FRAME_BYTES)? {
        ReadOutcome::Frame(kind::METRICS, p) => {
            String::from_utf8(p).or_net("metrics reply is not UTF-8")
        }
        ReadOutcome::Frame(k, _) => {
            Err(Error::net(format!("unexpected frame kind {k:#04x} awaiting metrics")))
        }
        ReadOutcome::Eof => Err(Error::net("connection closed awaiting metrics")),
        ReadOutcome::TimedOut => Err(Error::net("timed out awaiting metrics")),
    }
}
