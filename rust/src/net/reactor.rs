//! A minimal readiness facility for the net front-end: a dependency-free
//! wrapper over `poll(2)` (std + a single raw libc binding, no crates).
//!
//! The serving loop in `tcp.rs` registers every socket it owns each
//! tick, polls with a bounded timeout, and reads readiness back by
//! token. The API is deliberately level-triggered and rebuilt per tick
//! — with one reactor thread owning every connection there is nothing
//! to synchronise, and the poll set for a few thousand fds rebuilds in
//! microseconds.
//!
//! On non-unix targets (no `poll`) the set degrades to "everything is
//! ready" after a short sleep: all sockets the reactor drives are
//! nonblocking, so spurious readiness costs a `WouldBlock` syscall, not
//! correctness. That keeps the state machines portable and testable
//! while the fast path stays a real kernel wait on unix.

use std::time::Duration;

/// Readiness/interest bit: the fd can be read (or has an error/hangup
/// condition to collect via `read`).
pub const READ: u8 = 0b01;
/// Readiness/interest bit: the fd can accept writes.
pub const WRITE: u8 = 0b10;

/// Raw fd type the poll set registers. On non-unix targets the value is
/// carried but never handed to the kernel.
#[cfg(unix)]
pub type Fd = std::os::unix::io::RawFd;
#[cfg(not(unix))]
pub type Fd = i32;

/// The registered fd of a TCP stream.
pub fn stream_fd(s: &std::net::TcpStream) -> Fd {
    #[cfg(unix)]
    {
        use std::os::unix::io::AsRawFd;
        s.as_raw_fd()
    }
    #[cfg(not(unix))]
    {
        let _ = s;
        0
    }
}

/// The registered fd of a TCP listener.
pub fn listener_fd(l: &std::net::TcpListener) -> Fd {
    #[cfg(unix)]
    {
        use std::os::unix::io::AsRawFd;
        l.as_raw_fd()
    }
    #[cfg(not(unix))]
    {
        let _ = l;
        0
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_short};

    // nfds_t is `unsigned long` on Linux/glibc and `unsigned int` on
    // the BSDs/macOS; cover both without pulling in libc.
    #[cfg(any(target_os = "macos", target_os = "freebsd", target_os = "openbsd"))]
    pub type NfdsT = std::os::raw::c_uint;
    #[cfg(not(any(target_os = "macos", target_os = "freebsd", target_os = "openbsd")))]
    pub type NfdsT = std::os::raw::c_ulong;

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
    pub const POLLNVAL: c_short = 0x020;

    /// `struct pollfd` — identical layout on every unix.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }
}

/// One tick's worth of fds to wait on. `clear` + `register` each tick,
/// `poll` once, then query `readiness` by the token `register` returned.
#[derive(Default)]
pub struct PollSet {
    #[cfg(unix)]
    fds: Vec<sys::PollFd>,
    #[cfg(not(unix))]
    interests: Vec<u8>,
}

impl PollSet {
    pub fn new() -> PollSet {
        PollSet::default()
    }

    /// Drop every registration (keeps the allocation).
    pub fn clear(&mut self) {
        #[cfg(unix)]
        self.fds.clear();
        #[cfg(not(unix))]
        self.interests.clear();
    }

    /// Register `fd` with an interest mask (`READ | WRITE` bits; an
    /// empty mask still registers the fd for error conditions). Returns
    /// the token to pass to [`readiness`](Self::readiness) after the
    /// poll.
    pub fn register(&mut self, fd: Fd, interest: u8) -> usize {
        #[cfg(unix)]
        {
            let mut events = 0;
            if interest & READ != 0 {
                events |= sys::POLLIN;
            }
            if interest & WRITE != 0 {
                events |= sys::POLLOUT;
            }
            self.fds.push(sys::PollFd { fd, events, revents: 0 });
            self.fds.len() - 1
        }
        #[cfg(not(unix))]
        {
            let _ = fd;
            self.interests.push(interest);
            self.interests.len() - 1
        }
    }

    /// Number of registered fds this tick.
    pub fn len(&self) -> usize {
        #[cfg(unix)]
        {
            self.fds.len()
        }
        #[cfg(not(unix))]
        {
            self.interests.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Wait until at least one registered fd is ready or the timeout
    /// elapses. Returns the number of ready fds (0 on timeout). EINTR
    /// is treated as a timeout: the caller's loop re-polls anyway.
    pub fn poll(&mut self, timeout: Duration) -> usize {
        #[cfg(unix)]
        {
            let ms: i32 = timeout.as_millis().min(i32::MAX as u128) as i32;
            if self.fds.is_empty() {
                std::thread::sleep(timeout);
                return 0;
            }
            let n = unsafe {
                sys::poll(self.fds.as_mut_ptr(), self.fds.len() as sys::NfdsT, ms)
            };
            n.max(0) as usize
        }
        #[cfg(not(unix))]
        {
            // fallback: a short sleep, then report everything ready for
            // its interest; nonblocking sockets make that safe
            std::thread::sleep(timeout.min(Duration::from_millis(1)));
            self.interests.len()
        }
    }

    /// Readiness of a registered fd after [`poll`](Self::poll), as
    /// `READ | WRITE` bits. Error/hangup conditions are folded into
    /// both bits so the owner discovers them on its next `read`/`write`.
    pub fn readiness(&self, token: usize) -> u8 {
        #[cfg(unix)]
        {
            let r = self.fds[token].revents;
            let fatal = r & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0;
            let mut out = 0;
            if fatal || r & sys::POLLIN != 0 {
                out |= READ;
            }
            if fatal || r & sys::POLLOUT != 0 {
                out |= WRITE;
            }
            out
        }
        #[cfg(not(unix))]
        {
            self.interests[token]
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn listener_becomes_readable_on_connect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut set = PollSet::new();
        set.register(listener_fd(&listener), READ);
        assert_eq!(set.poll(Duration::from_millis(10)), 0, "no pending connect yet");

        let _client = TcpStream::connect(addr).unwrap();
        set.clear();
        let tok = set.register(listener_fd(&listener), READ);
        assert!(set.poll(Duration::from_millis(2000)) >= 1);
        assert_eq!(set.readiness(tok) & READ, READ);
    }

    #[test]
    fn stream_readiness_tracks_data_and_writability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        // a fresh socket: writable, nothing to read
        let mut set = PollSet::new();
        let tok = set.register(stream_fd(&server), READ | WRITE);
        assert!(set.poll(Duration::from_millis(2000)) >= 1);
        assert_eq!(set.readiness(tok) & WRITE, WRITE);
        assert_eq!(set.readiness(tok) & READ, 0);

        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        set.clear();
        let tok = set.register(stream_fd(&server), READ);
        assert!(set.poll(Duration::from_millis(2000)) >= 1);
        assert_eq!(set.readiness(tok) & READ, READ);
    }

    #[test]
    fn hangup_reads_as_readable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        drop(client);
        // peer closed: POLLIN/POLLHUP — either way the READ bit is set
        // so the owner reads the EOF
        let mut set = PollSet::new();
        let tok = set.register(stream_fd(&server), READ);
        assert!(set.poll(Duration::from_millis(2000)) >= 1);
        assert_eq!(set.readiness(tok) & READ, READ);
    }
}
