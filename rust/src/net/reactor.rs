//! A minimal readiness facility for the net front-end: dependency-free
//! wrappers over `poll(2)` and Linux `epoll(7)` (std + raw extern-C
//! bindings, no crates), selected at runtime by [`PollerKind`].
//!
//! The serving loop in `tcp.rs` registers every socket it owns each
//! tick, polls with a bounded timeout, and reads readiness back by
//! token. The API is deliberately level-triggered and rebuilt per tick
//! — with one reactor thread owning every connection there is nothing
//! to synchronise. The `poll(2)` backend hands the whole fd list to the
//! kernel each tick (O(fds) per wakeup); the `epoll` backend keeps a
//! persistent kernel interest set and only issues `epoll_ctl` for fds
//! whose interest actually changed, so a wakeup costs O(ready) — the
//! difference that matters at thousands of mostly-idle sessions.
//!
//! Both backends expose identical semantics, pinned by
//! `tests/reactor_conformance.rs`: the same READ/WRITE interest bits,
//! error/hangup conditions folded into both readiness bits, and EINTR
//! treated as a timeout.
//!
//! One contract the epoll backend adds (and the tcp reactor satisfies
//! by construction): a closed fd's *number* must be absent from at
//! least one tick's registrations before a reused fd is registered
//! again. The reactor accepts new sockets before it reaps closed ones
//! within a tick, so a reused fd number always sees an intervening
//! tick in which the stale registration is dropped from the kernel set.
//!
//! On non-unix targets (no `poll`) the set degrades to "everything is
//! ready" after a bounded sleep ([`FallbackSet`]): all sockets the
//! reactor drives are nonblocking, so spurious readiness costs a
//! `WouldBlock` syscall, not correctness.

use std::time::Duration;

/// Readiness/interest bit: the fd can be read (or has an error/hangup
/// condition to collect via `read`).
pub const READ: u8 = 0b01;
/// Readiness/interest bit: the fd can accept writes.
pub const WRITE: u8 = 0b10;

/// Which kernel readiness backend a [`PollSet`] runs on (the
/// `net.poller` knob: TOML `[net] poller`, `tcvd serve --poller`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PollerKind {
    /// Pick the best backend for the platform: `epoll` on Linux,
    /// `poll(2)` elsewhere.
    #[default]
    Auto,
    /// The portable `poll(2)` backend (O(fds) per wakeup).
    Poll,
    /// The Linux `epoll` backend (O(ready) per wakeup). Degrades to
    /// `poll(2)` on other platforms or if the epoll instance cannot be
    /// created.
    Epoll,
}

impl PollerKind {
    /// Parse a `net.poller` knob value (`"auto" | "poll" | "epoll"`).
    pub fn parse(name: &str) -> Option<PollerKind> {
        match name {
            "auto" => Some(PollerKind::Auto),
            "poll" => Some(PollerKind::Poll),
            "epoll" => Some(PollerKind::Epoll),
            _ => None,
        }
    }

    /// The knob spelling of this kind.
    pub const fn name(self) -> &'static str {
        match self {
            PollerKind::Auto => "auto",
            PollerKind::Poll => "poll",
            PollerKind::Epoll => "epoll",
        }
    }

    /// The concrete backend this kind selects on the current platform
    /// (never returns `Auto`; `Epoll` degrades to `Poll` off Linux).
    pub fn resolve(self) -> PollerKind {
        match self {
            PollerKind::Poll => PollerKind::Poll,
            PollerKind::Auto | PollerKind::Epoll => {
                #[cfg(target_os = "linux")]
                {
                    PollerKind::Epoll
                }
                #[cfg(not(target_os = "linux"))]
                {
                    PollerKind::Poll
                }
            }
        }
    }
}

/// Raw fd type the poll set registers. On non-unix targets the value is
/// carried but never handed to the kernel.
#[cfg(unix)]
pub type Fd = std::os::unix::io::RawFd;
#[cfg(not(unix))]
pub type Fd = i32;

/// The registered fd of a TCP stream.
pub fn stream_fd(s: &std::net::TcpStream) -> Fd {
    #[cfg(unix)]
    {
        use std::os::unix::io::AsRawFd;
        s.as_raw_fd()
    }
    #[cfg(not(unix))]
    {
        let _ = s;
        0
    }
}

/// The registered fd of a TCP listener.
pub fn listener_fd(l: &std::net::TcpListener) -> Fd {
    #[cfg(unix)]
    {
        use std::os::unix::io::AsRawFd;
        l.as_raw_fd()
    }
    #[cfg(not(unix))]
    {
        let _ = l;
        0
    }
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_short};

    // nfds_t is `unsigned long` on Linux/glibc and `unsigned int` on
    // the BSDs/macOS; cover both without pulling in libc.
    #[cfg(any(target_os = "macos", target_os = "freebsd", target_os = "openbsd"))]
    pub type NfdsT = std::os::raw::c_uint;
    #[cfg(not(any(target_os = "macos", target_os = "freebsd", target_os = "openbsd")))]
    pub type NfdsT = std::os::raw::c_ulong;

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
    pub const POLLNVAL: c_short = 0x020;

    /// `struct pollfd` — identical layout on every unix.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }
}

#[cfg(target_os = "linux")]
mod esys {
    use std::os::raw::c_int;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;

    pub const ENOENT: i32 = 2;
    pub const EEXIST: i32 = 17;

    /// `struct epoll_event`. The kernel ABI packs it on x86-64 (to keep
    /// the 32-bit layout); it is naturally aligned everywhere else.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// The `poll(2)` backend: the fd list is handed to the kernel whole,
/// every tick.
#[cfg(unix)]
#[derive(Default)]
struct PollVec {
    fds: Vec<sys::PollFd>,
}

#[cfg(unix)]
impl PollVec {
    fn clear(&mut self) {
        self.fds.clear();
    }

    fn register(&mut self, fd: Fd, interest: u8) -> usize {
        let mut events = 0;
        if interest & READ != 0 {
            events |= sys::POLLIN;
        }
        if interest & WRITE != 0 {
            events |= sys::POLLOUT;
        }
        self.fds.push(sys::PollFd { fd, events, revents: 0 });
        self.fds.len() - 1
    }

    fn len(&self) -> usize {
        self.fds.len()
    }

    fn poll(&mut self, timeout: Duration) -> usize {
        let ms: i32 = timeout.as_millis().min(i32::MAX as u128) as i32;
        if self.fds.is_empty() {
            std::thread::sleep(timeout);
            return 0;
        }
        let n =
            unsafe { sys::poll(self.fds.as_mut_ptr(), self.fds.len() as sys::NfdsT, ms) };
        n.max(0) as usize
    }

    fn readiness(&self, token: usize) -> u8 {
        let r = self.fds[token].revents;
        let fatal = r & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0;
        let mut out = 0;
        if fatal || r & sys::POLLIN != 0 {
            out |= READ;
        }
        if fatal || r & sys::POLLOUT != 0 {
            out |= WRITE;
        }
        out
    }
}

/// The `epoll` backend: one persistent kernel interest set, reconciled
/// against this tick's registrations with `epoll_ctl` only where the
/// interest actually changed (steady state: zero ctl syscalls, one
/// `epoll_wait` returning only the ready fds).
#[cfg(target_os = "linux")]
struct EpollBackend {
    epfd: std::os::raw::c_int,
    /// Interest mask the kernel currently holds, per fd.
    installed: std::collections::HashMap<Fd, u32>,
    /// This tick's registrations, in token order.
    entries: Vec<(Fd, u8)>,
    tok_by_fd: std::collections::HashMap<Fd, usize>,
    /// Readiness per token, filled by [`poll`](Self::poll).
    revents: Vec<u8>,
    events_buf: Vec<esys::EpollEvent>,
    stale: Vec<Fd>,
}

#[cfg(target_os = "linux")]
impl EpollBackend {
    fn new() -> Option<EpollBackend> {
        let epfd = unsafe { esys::epoll_create1(esys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return None;
        }
        Some(EpollBackend {
            epfd,
            installed: std::collections::HashMap::new(),
            entries: Vec::new(),
            tok_by_fd: std::collections::HashMap::new(),
            revents: Vec::new(),
            events_buf: Vec::new(),
            stale: Vec::new(),
        })
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.tok_by_fd.clear();
        self.revents.clear();
    }

    fn register(&mut self, fd: Fd, interest: u8) -> usize {
        let token = self.entries.len();
        self.entries.push((fd, interest));
        self.tok_by_fd.insert(fd, token);
        token
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn want_events(interest: u8) -> u32 {
        let mut want = 0;
        if interest & READ != 0 {
            want |= esys::EPOLLIN;
        }
        if interest & WRITE != 0 {
            want |= esys::EPOLLOUT;
        }
        want
    }

    fn ctl(&self, op: std::os::raw::c_int, fd: Fd, events: u32) -> std::io::Result<()> {
        let mut ev = esys::EpollEvent { events, data: fd as u64 };
        let rc = unsafe { esys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc == 0 {
            Ok(())
        } else {
            Err(std::io::Error::last_os_error())
        }
    }

    /// Reconcile the kernel interest set with this tick's entries.
    fn sync(&mut self) {
        for i in 0..self.entries.len() {
            let (fd, interest) = self.entries[i];
            let want = Self::want_events(interest);
            match self.installed.get(&fd).copied() {
                Some(have) if have == want => {}
                Some(_) => {
                    // a closed-and-reused fd number was dropped from the
                    // kernel set automatically: MOD answers ENOENT, and
                    // the ADD retry re-installs it
                    let ok = match self.ctl(esys::EPOLL_CTL_MOD, fd, want) {
                        Ok(()) => true,
                        Err(e) if e.raw_os_error() == Some(esys::ENOENT) => {
                            self.ctl(esys::EPOLL_CTL_ADD, fd, want).is_ok()
                        }
                        Err(_) => false,
                    };
                    if ok {
                        self.installed.insert(fd, want);
                    } else {
                        self.installed.remove(&fd);
                    }
                }
                None => {
                    let ok = match self.ctl(esys::EPOLL_CTL_ADD, fd, want) {
                        Ok(()) => true,
                        Err(e) if e.raw_os_error() == Some(esys::EEXIST) => {
                            self.ctl(esys::EPOLL_CTL_MOD, fd, want).is_ok()
                        }
                        Err(_) => false,
                    };
                    if ok {
                        self.installed.insert(fd, want);
                    }
                }
            }
        }
        // deregister fds that vanished from the tick (DEL on an
        // already-closed fd fails harmlessly: the kernel dropped it)
        self.stale.clear();
        for &fd in self.installed.keys() {
            if !self.tok_by_fd.contains_key(&fd) {
                self.stale.push(fd);
            }
        }
        for i in 0..self.stale.len() {
            let fd = self.stale[i];
            let _ = self.ctl(esys::EPOLL_CTL_DEL, fd, 0);
            self.installed.remove(&fd);
        }
    }

    fn poll(&mut self, timeout: Duration) -> usize {
        self.sync();
        self.revents.clear();
        self.revents.resize(self.entries.len(), 0);
        if self.entries.is_empty() {
            std::thread::sleep(timeout);
            return 0;
        }
        let ms: i32 = timeout.as_millis().min(i32::MAX as u128) as i32;
        self.events_buf
            .resize(self.entries.len().max(8), esys::EpollEvent { events: 0, data: 0 });
        let n = unsafe {
            esys::epoll_wait(
                self.epfd,
                self.events_buf.as_mut_ptr(),
                self.events_buf.len() as std::os::raw::c_int,
                ms,
            )
        };
        if n <= 0 {
            return 0; // timeout, or EINTR treated as one
        }
        let mut ready = 0;
        for ev in &self.events_buf[..n as usize] {
            let ev = *ev; // copy out of the (possibly packed) buffer
            let Some(&tok) = self.tok_by_fd.get(&(ev.data as Fd)) else { continue };
            let fatal = ev.events & (esys::EPOLLERR | esys::EPOLLHUP) != 0;
            let mut bits = 0;
            if fatal || ev.events & esys::EPOLLIN != 0 {
                bits |= READ;
            }
            if fatal || ev.events & esys::EPOLLOUT != 0 {
                bits |= WRITE;
            }
            if bits != 0 && self.revents[tok] == 0 {
                ready += 1;
            }
            self.revents[tok] |= bits;
        }
        ready
    }

    fn readiness(&self, token: usize) -> u8 {
        self.revents[token]
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollBackend {
    fn drop(&mut self) {
        unsafe {
            esys::close(self.epfd);
        }
    }
}

/// Minimum sleep of the [`FallbackSet`] degraded poller: the same floor
/// the reactor's self-tuning idle tick clamps to. Every fd reports
/// ready every tick on this backend, so sleeping less than the tick
/// floor (as the pre-PR-10 fallback did with its 1 ms cap) busy-spins
/// the reactor at high fd counts.
pub const FALLBACK_MIN_SLEEP: Duration = Duration::from_millis(5);

/// The degraded poller for targets with no kernel readiness facility:
/// every registered fd reports ready for its full interest after a
/// bounded sleep. Spurious readiness is safe (the reactor's sockets are
/// nonblocking), and the sleep honors the requested timeout with a
/// [`FALLBACK_MIN_SLEEP`] floor so the loop cannot busy-spin.
///
/// Compiled on every target so its timing contract stays unit-tested
/// from unix CI; it is only wired up as the live [`PollSet`] backend on
/// non-unix targets.
#[derive(Default)]
pub struct FallbackSet {
    interests: Vec<u8>,
}

impl FallbackSet {
    pub fn new() -> FallbackSet {
        FallbackSet::default()
    }

    pub fn clear(&mut self) {
        self.interests.clear();
    }

    pub fn register(&mut self, fd: Fd, interest: u8) -> usize {
        let _ = fd;
        self.interests.push(interest);
        self.interests.len() - 1
    }

    pub fn len(&self) -> usize {
        self.interests.len()
    }

    /// Sleep `timeout` (at least [`FALLBACK_MIN_SLEEP`]), then report
    /// every fd ready for its registered interest.
    pub fn poll(&mut self, timeout: Duration) -> usize {
        std::thread::sleep(timeout.max(FALLBACK_MIN_SLEEP));
        self.interests.len()
    }

    pub fn readiness(&self, token: usize) -> u8 {
        self.interests[token]
    }
}

enum Backend {
    #[cfg(unix)]
    Poll(PollVec),
    #[cfg(target_os = "linux")]
    Epoll(EpollBackend),
    #[cfg(not(unix))]
    Fallback(FallbackSet),
}

/// One tick's worth of fds to wait on. `clear` + `register` each tick,
/// `poll` once, then query `readiness` by the token `register` returned.
/// The kernel backend is chosen at construction ([`PollerKind`]);
/// [`new`](Self::new) follows `Auto`.
pub struct PollSet {
    backend: Backend,
}

impl Default for PollSet {
    fn default() -> Self {
        PollSet::new()
    }
}

impl PollSet {
    pub fn new() -> PollSet {
        PollSet::with_poller(PollerKind::Auto)
    }

    /// A poll set on the backend `kind` selects (see
    /// [`PollerKind::resolve`]; an epoll instance that cannot be
    /// created degrades to `poll(2)`).
    pub fn with_poller(kind: PollerKind) -> PollSet {
        #[cfg(not(unix))]
        {
            let _ = kind;
            PollSet { backend: Backend::Fallback(FallbackSet::new()) }
        }
        #[cfg(unix)]
        {
            match kind.resolve() {
                #[cfg(target_os = "linux")]
                PollerKind::Epoll => match EpollBackend::new() {
                    Some(e) => PollSet { backend: Backend::Epoll(e) },
                    None => PollSet { backend: Backend::Poll(PollVec::default()) },
                },
                _ => PollSet { backend: Backend::Poll(PollVec::default()) },
            }
        }
    }

    /// The live backend's name: `"poll"`, `"epoll"` or `"fallback"`
    /// (feeds the `net.poller` metrics gauge).
    pub fn kind(&self) -> &'static str {
        match &self.backend {
            #[cfg(unix)]
            Backend::Poll(_) => "poll",
            #[cfg(target_os = "linux")]
            Backend::Epoll(_) => "epoll",
            #[cfg(not(unix))]
            Backend::Fallback(_) => "fallback",
        }
    }

    /// Drop every registration (keeps allocations and, on epoll, the
    /// kernel interest set — reconciled lazily at the next `poll`).
    pub fn clear(&mut self) {
        match &mut self.backend {
            #[cfg(unix)]
            Backend::Poll(b) => b.clear(),
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.clear(),
            #[cfg(not(unix))]
            Backend::Fallback(b) => b.clear(),
        }
    }

    /// Register `fd` with an interest mask (`READ | WRITE` bits; an
    /// empty mask still registers the fd for error conditions). Returns
    /// the token to pass to [`readiness`](Self::readiness) after the
    /// poll. Register each fd at most once per tick.
    pub fn register(&mut self, fd: Fd, interest: u8) -> usize {
        match &mut self.backend {
            #[cfg(unix)]
            Backend::Poll(b) => b.register(fd, interest),
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.register(fd, interest),
            #[cfg(not(unix))]
            Backend::Fallback(b) => b.register(fd, interest),
        }
    }

    /// Number of registered fds this tick.
    pub fn len(&self) -> usize {
        match &self.backend {
            #[cfg(unix)]
            Backend::Poll(b) => b.len(),
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.len(),
            #[cfg(not(unix))]
            Backend::Fallback(b) => b.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Wait until at least one registered fd is ready or the timeout
    /// elapses. Returns the number of ready fds (0 on timeout). EINTR
    /// is treated as a timeout: the caller's loop re-polls anyway.
    pub fn poll(&mut self, timeout: Duration) -> usize {
        match &mut self.backend {
            #[cfg(unix)]
            Backend::Poll(b) => b.poll(timeout),
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.poll(timeout),
            #[cfg(not(unix))]
            Backend::Fallback(b) => b.poll(timeout),
        }
    }

    /// Readiness of a registered fd after [`poll`](Self::poll), as
    /// `READ | WRITE` bits. Error/hangup conditions are folded into
    /// both bits so the owner discovers them on its next `read`/`write`.
    pub fn readiness(&self, token: usize) -> u8 {
        match &self.backend {
            #[cfg(unix)]
            Backend::Poll(b) => b.readiness(token),
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.readiness(token),
            #[cfg(not(unix))]
            Backend::Fallback(b) => b.readiness(token),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poller_kind_parses_knob_values() {
        assert_eq!(PollerKind::parse("auto"), Some(PollerKind::Auto));
        assert_eq!(PollerKind::parse("poll"), Some(PollerKind::Poll));
        assert_eq!(PollerKind::parse("epoll"), Some(PollerKind::Epoll));
        assert_eq!(PollerKind::parse("kqueue"), None);
        assert_eq!(PollerKind::Poll.resolve(), PollerKind::Poll);
        assert_ne!(PollerKind::Auto.resolve(), PollerKind::Auto, "auto resolves concretely");
    }

    #[test]
    fn fallback_sleeps_at_least_the_tick_floor() {
        // the busy-spin regression: a sub-floor timeout must still cost
        // a full FALLBACK_MIN_SLEEP, because every fd will report ready
        let mut set = FallbackSet::new();
        for fd in 0..32 {
            set.register(fd, READ | WRITE);
        }
        let t0 = std::time::Instant::now();
        let ready = set.poll(Duration::from_millis(1));
        let elapsed = t0.elapsed();
        assert_eq!(ready, 32, "fallback reports every fd ready");
        assert!(
            elapsed >= Duration::from_millis(4),
            "sub-floor timeout slept only {elapsed:?} (floor is {FALLBACK_MIN_SLEEP:?})"
        );
        // and a timeout above the floor is honored in full, not capped
        // at the old 1 ms ceiling
        let t0 = std::time::Instant::now();
        set.poll(Duration::from_millis(25));
        assert!(t0.elapsed() >= Duration::from_millis(20), "fallback honors long timeouts");
    }

    #[test]
    fn fallback_readiness_echoes_interest() {
        let mut set = FallbackSet::new();
        let a = set.register(3, READ);
        let b = set.register(4, WRITE);
        set.poll(Duration::from_millis(1));
        assert_eq!(set.readiness(a), READ);
        assert_eq!(set.readiness(b), WRITE);
    }
}

#[cfg(all(test, unix))]
mod unix_tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    fn backends() -> Vec<PollerKind> {
        // PollerKind::Epoll degrades to poll off Linux, so this list is
        // safe (if redundant) everywhere
        vec![PollerKind::Poll, PollerKind::Epoll]
    }

    #[test]
    fn listener_becomes_readable_on_connect() {
        for kind in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut set = PollSet::with_poller(kind);
            set.register(listener_fd(&listener), READ);
            assert_eq!(set.poll(Duration::from_millis(10)), 0, "no pending connect yet");

            let _client = TcpStream::connect(addr).unwrap();
            set.clear();
            let tok = set.register(listener_fd(&listener), READ);
            assert!(set.poll(Duration::from_millis(2000)) >= 1, "{}", set.kind());
            assert_eq!(set.readiness(tok) & READ, READ, "{}", set.kind());
        }
    }

    #[test]
    fn stream_readiness_tracks_data_and_writability() {
        for kind in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut client = TcpStream::connect(addr).unwrap();
            let (server, _) = listener.accept().unwrap();
            server.set_nonblocking(true).unwrap();

            // a fresh socket: writable, nothing to read
            let mut set = PollSet::with_poller(kind);
            let tok = set.register(stream_fd(&server), READ | WRITE);
            assert!(set.poll(Duration::from_millis(2000)) >= 1, "{}", set.kind());
            assert_eq!(set.readiness(tok) & WRITE, WRITE, "{}", set.kind());
            assert_eq!(set.readiness(tok) & READ, 0, "{}", set.kind());

            client.write_all(b"ping").unwrap();
            client.flush().unwrap();
            set.clear();
            let tok = set.register(stream_fd(&server), READ);
            assert!(set.poll(Duration::from_millis(2000)) >= 1, "{}", set.kind());
            assert_eq!(set.readiness(tok) & READ, READ, "{}", set.kind());
        }
    }

    #[test]
    fn hangup_reads_as_readable() {
        for kind in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let client = TcpStream::connect(addr).unwrap();
            let (server, _) = listener.accept().unwrap();
            drop(client);
            // peer closed: POLLIN/POLLHUP — either way the READ bit is
            // set so the owner reads the EOF
            let mut set = PollSet::with_poller(kind);
            let tok = set.register(stream_fd(&server), READ);
            assert!(set.poll(Duration::from_millis(2000)) >= 1, "{}", set.kind());
            assert_eq!(set.readiness(tok) & READ, READ, "{}", set.kind());
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn auto_and_epoll_select_the_kernel_backend_on_linux() {
        assert_eq!(PollSet::with_poller(PollerKind::Auto).kind(), "epoll");
        assert_eq!(PollSet::with_poller(PollerKind::Epoll).kind(), "epoll");
        assert_eq!(PollSet::with_poller(PollerKind::Poll).kind(), "poll");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_interest_changes_and_deregistration_reconcile() {
        // exercises the MOD / DEL / re-ADD paths of the persistent
        // kernel interest set across ticks
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let mut set = PollSet::with_poller(PollerKind::Epoll);
        assert_eq!(set.kind(), "epoll");

        // tick 1: WRITE interest — writable
        let tok = set.register(stream_fd(&server), WRITE);
        assert!(set.poll(Duration::from_millis(2000)) >= 1);
        assert_eq!(set.readiness(tok), WRITE);

        // tick 2: MOD down to READ-only — quiet socket, nothing ready
        set.clear();
        let tok = set.register(stream_fd(&server), READ);
        assert_eq!(set.poll(Duration::from_millis(20)), 0);
        assert_eq!(set.readiness(tok), 0);

        // tick 3: deregistered — data arriving must not be reported
        client.write_all(b"x").unwrap();
        set.clear();
        assert_eq!(set.poll(Duration::from_millis(20)), 0);

        // tick 4: re-registered (the DEL → ADD round trip) — the
        // buffered byte is readable again
        set.clear();
        let tok = set.register(stream_fd(&server), READ);
        assert!(set.poll(Duration::from_millis(2000)) >= 1);
        assert_eq!(set.readiness(tok) & READ, READ);
    }
}
