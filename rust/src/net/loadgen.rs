//! Loopback load/soak harness for the socket front-end: N worker
//! threads churn concurrent sessions against a running server — a
//! fresh TCP connection per block (so the session lifecycle — admit /
//! evict / shed — is exercised continuously, not just the steady
//! state), or one pipelined ack-windowed UDP flow per worker — and
//! every decoded block is checked **bit-identical** against a one-shot
//! [`Decoder`](crate::Decoder) oracle decoding the same LLRs
//! in-process.
//!
//! The oracle runs **once, up front**: the harness precomputes a pool
//! of distinct workloads (LLRs + oracle bits) and the workers share it
//! read-only. Workers are thin socket drivers on small stacks, which is
//! what makes `--sessions 4096` tractable — the pre-PR-10 harness built
//! a full oracle pipeline (engine threads and all) inside every worker.
//!
//! Shed rejections are retried (and counted), so a run against an
//! undersized server converges instead of failing; mismatches and
//! hard failures never retry. Latency samples are the successful
//! attempt's own end-to-end measurement (TCP: FINISH to the last
//! decoded byte; UDP: first send of a block to its OK reply) — shed
//! attempts never contribute a sample. The aggregate throughput /
//! latency numbers feed `scripts/bench_snapshot.py`'s `net` section;
//! the `loadgen` binary wraps this with CLI flags and JSON output.

use std::time::{Duration, Instant};

use crate::api::{DecoderBuilder, TerminationMode};
use crate::channel::awgn::AwgnChannel;
use crate::channel::bpsk;
use crate::coding::{registry, Code, Encoder};
use crate::defaults;
use crate::error::{Error, Result};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::tcp::TcpClient;
use super::udp::{UdpClient, UdpPipelineOptions};

/// Which transport the harness drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Tcp,
    Udp,
}

impl Transport {
    pub fn name(&self) -> &'static str {
        match self {
            Transport::Tcp => "tcp",
            Transport::Udp => "udp",
        }
    }
}

/// Harness parameters.
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// Concurrent worker threads (each worker is one live session at a
    /// time, reconnecting per block — session churn).
    pub sessions: usize,
    /// Blocks each worker decodes.
    pub blocks_per_session: usize,
    /// Trellis stages per block (must be a multiple of the tile
    /// payload).
    pub block_stages: usize,
    /// AWGN channel Eb/N0 in dB.
    pub ebn0_db: f64,
    /// Workload RNG seed.
    pub seed: u64,
    /// Transport to drive.
    pub transport: Transport,
    /// Give up on one block after this many shed-retries.
    pub max_retries: usize,
    /// TCP: offer a CRC32 on every DATA frame in the HELLO.
    pub crc: bool,
    /// UDP: ack-window size of the pipelined per-worker flow.
    pub udp_window: usize,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            sessions: 8,
            blocks_per_session: 4,
            block_stages: 256,
            ebn0_db: 5.0,
            seed: 1,
            transport: Transport::Tcp,
            max_retries: 200,
            crc: false,
            udp_window: defaults::NET_UDP_WINDOW,
        }
    }
}

/// Aggregated result of one harness run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub transport: String,
    pub sessions: usize,
    /// Blocks decoded and verified.
    pub blocks: u64,
    /// Shed rejections observed (each was retried).
    pub shed_retries: u64,
    /// Blocks abandoned after `max_retries` sheds or a hard error.
    pub failures: u64,
    /// Worker threads that panicked. Their blocks are additionally
    /// counted under `failures`; a nonzero count fails
    /// [`check`](Self::check) but never aborts the harness process.
    pub worker_panics: u64,
    /// Blocks whose bits differed from the in-process oracle.
    pub mismatches: u64,
    /// Total decoded payload bits across all verified blocks.
    pub payload_bits: u64,
    /// Wall-clock run time.
    pub elapsed_s: f64,
    /// Aggregate decoded throughput across all sessions, Mb/s.
    pub aggregate_mbps: f64,
    /// Per-block end-to-end latency percentiles, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl LoadgenReport {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("transport", json::s(&self.transport)),
            ("sessions", json::num(self.sessions as f64)),
            ("blocks", json::num(self.blocks as f64)),
            ("shed_retries", json::num(self.shed_retries as f64)),
            ("failures", json::num(self.failures as f64)),
            ("worker_panics", json::num(self.worker_panics as f64)),
            ("mismatches", json::num(self.mismatches as f64)),
            ("payload_bits", json::num(self.payload_bits as f64)),
            ("elapsed_s", json::num(self.elapsed_s)),
            ("aggregate_mbps", json::num(self.aggregate_mbps)),
            ("p50_ms", json::num(self.p50_ms)),
            ("p99_ms", json::num(self.p99_ms)),
        ])
    }

    /// Soak verdict: every block verified bit-identical, nothing
    /// abandoned, optional latency/throughput bounds hold.
    pub fn check(&self, max_p99_ms: Option<f64>, min_aggregate_mbps: Option<f64>) -> Result<()> {
        if self.mismatches > 0 {
            return Err(Error::net(format!(
                "{} of {} blocks differed from the in-process oracle",
                self.mismatches, self.blocks
            )));
        }
        if self.worker_panics > 0 {
            return Err(Error::net(format!(
                "{} loadgen worker thread(s) panicked",
                self.worker_panics
            )));
        }
        if self.failures > 0 {
            return Err(Error::net(format!("{} blocks failed or were abandoned", self.failures)));
        }
        if let Some(bound) = max_p99_ms {
            if self.p99_ms > bound {
                return Err(Error::net(format!(
                    "p99 latency {:.3} ms exceeds the {bound:.3} ms bound",
                    self.p99_ms
                )));
            }
        }
        if let Some(bound) = min_aggregate_mbps {
            if self.aggregate_mbps < bound {
                return Err(Error::net(format!(
                    "aggregate throughput {:.3} Mb/s is under the {bound:.3} Mb/s bound",
                    self.aggregate_mbps
                )));
            }
        }
        Ok(())
    }
}

/// Synthesize one block's LLRs: random payload, terminated encode per
/// the mode, BPSK + AWGN at `ebn0_db`. `stages` is the trellis length
/// of the resulting stream.
pub fn make_block_llrs(
    code: &Code,
    mode: TerminationMode,
    stages: usize,
    ebn0_db: f64,
    seed: u64,
) -> Vec<f32> {
    let memory = (code.k() - 1) as usize;
    let info = match mode {
        TerminationMode::Flushed => stages.saturating_sub(memory).max(1),
        _ => stages,
    };
    let bits = Rng::new(seed).bits(info);
    let mut enc = Encoder::new(code.clone());
    let (coded, n) = enc.encode_terminated(&bits, mode);
    debug_assert_eq!(n, stages, "workload stage accounting");
    let tx = bpsk::modulate(&coded);
    let rate = 1.0 / code.beta() as f64;
    let mut ch = AwgnChannel::new(ebn0_db, rate, seed ^ 0x5EED_F00D);
    ch.transmit(&tx).iter().map(|&x| x as f32).collect()
}

fn is_shed(e: &Error) -> bool {
    matches!(e, Error::Net(m) if m.contains("rejected") || m.contains("shed"))
}

#[derive(Default)]
struct WorkerTally {
    blocks: u64,
    shed_retries: u64,
    failures: u64,
    mismatches: u64,
    payload_bits: u64,
    latencies_ms: Vec<f64>,
}

/// Run `attempt` until it returns bits or a non-shed error (or the
/// retry budget runs out). Exactly one latency sample — the successful
/// attempt's own measurement — lands in `tally` per decoded block;
/// shed attempts bump `shed_retries` and contribute nothing to the
/// percentiles.
fn decode_with_retries<F>(
    max_retries: usize,
    tally: &mut WorkerTally,
    mut attempt: F,
) -> Option<Vec<u8>>
where
    F: FnMut() -> Result<(Vec<u8>, Duration)>,
{
    let mut retries = 0;
    loop {
        match attempt() {
            Ok((bits, latency)) => {
                tally.latencies_ms.push(latency.as_secs_f64() * 1e3);
                return Some(bits);
            }
            Err(e) if is_shed(&e) && retries < max_retries => {
                retries += 1;
                tally.shed_retries += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => return None,
        }
    }
}

/// One precomputed block: its channel LLRs and the oracle's bits.
struct Workload {
    llr: Vec<f32>,
    want: Vec<u8>,
}

/// Distinct workloads to precompute. Capped so a 4096-session soak does
/// not spend its wall-clock in the oracle; workers cycle through the
/// pool, so every block is still verified against known-good bits.
const WORKLOAD_POOL_MAX: usize = 64;

/// Worker thread stack: the workers are thin socket drivers (the heavy
/// encode/decode work is precomputed), so thousands of them fit in a
/// modest address-space budget.
const WORKER_STACK: usize = 512 * 1024;

fn run_worker(
    addr: &str,
    builder: &DecoderBuilder,
    opts: &LoadgenOptions,
    pool: &[Workload],
    worker: usize,
) -> Result<WorkerTally> {
    let code = registry::lookup(builder.code_name()).map_err(Error::config)?;
    let beta = code.beta();
    let chunk_llrs = (builder.tile_config().payload * beta).max(beta);
    let mut tally = WorkerTally::default();
    // this worker's slice of the shared pool, offset so concurrent
    // workers spread across distinct workloads
    let workload =
        |block: usize| &pool[(worker * opts.blocks_per_session + block) % pool.len()];
    match opts.transport {
        // TCP: fresh session per block — connect, decode, disconnect —
        // so admission/eviction churns on every block
        Transport::Tcp => {
            for block in 0..opts.blocks_per_session {
                let Workload { llr, want } = workload(block);
                let got = decode_with_retries(opts.max_retries, &mut tally, || {
                    let mut c = TcpClient::connect_opts(addr, builder, opts.crc)?;
                    for chunk in llr.chunks(chunk_llrs) {
                        c.push(chunk)?;
                    }
                    c.finish_timed()
                });
                match got {
                    Some(bits) if &bits == want => {
                        tally.blocks += 1;
                        tally.payload_bits += bits.len() as u64;
                    }
                    Some(_) => tally.mismatches += 1,
                    None => tally.failures += 1,
                }
            }
        }
        // UDP: one flow per worker, all blocks pipelined behind the
        // ack window (shed replies retry inside the window)
        Transport::Udp => {
            let llrs: Vec<Vec<f32>> =
                (0..opts.blocks_per_session).map(|b| workload(b).llr.clone()).collect();
            let popts =
                UdpPipelineOptions { window: opts.udp_window, ..UdpPipelineOptions::default() };
            let run = UdpClient::connect(addr, worker as u64)
                .and_then(|mut c| c.decode_blocks(&llrs, &popts));
            match run {
                Ok(run) => {
                    tally.shed_retries += run.stats.shed_retries;
                    for ((bits, lat), block) in
                        run.blocks.iter().zip(&run.latencies).zip(0..)
                    {
                        if bits == &workload(block).want {
                            tally.blocks += 1;
                            tally.payload_bits += bits.len() as u64;
                            tally.latencies_ms.push(lat.as_secs_f64() * 1e3);
                        } else {
                            tally.mismatches += 1;
                        }
                    }
                }
                Err(_) => tally.failures += opts.blocks_per_session as u64,
            }
        }
    }
    Ok(tally)
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_ms.len() - 1) as f64).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// Run the harness against a server at `addr` (host:port; the UDP
/// transport interprets it as the server's UDP address). The builder
/// must describe the same pipeline the server runs — its parameters
/// drive both the HELLO handshake and the in-process oracle.
pub fn run(addr: &str, builder: &DecoderBuilder, opts: &LoadgenOptions) -> Result<LoadgenReport> {
    if opts.sessions == 0 || opts.blocks_per_session == 0 {
        return Err(Error::config("loadgen needs at least one session and one block"));
    }
    let tile = builder.tile_config();
    if opts.block_stages == 0 || opts.block_stages % tile.payload != 0 {
        return Err(Error::config(format!(
            "block_stages ({}) must be a positive multiple of the tile payload ({})",
            opts.block_stages, tile.payload
        )));
    }
    // precompute the shared workload pool with ONE oracle pipeline for
    // the whole run — the workers only drive sockets and compare bytes
    let mut oracle = builder.clone().shards(1).build()?;
    let code = registry::lookup(builder.code_name()).map_err(Error::config)?;
    let mode = builder.termination_mode();
    let total_blocks = opts.sessions.saturating_mul(opts.blocks_per_session);
    let pool_n = total_blocks.min(WORKLOAD_POOL_MAX).max(1);
    let mut pool = Vec::with_capacity(pool_n);
    for i in 0..pool_n {
        let seed = opts.seed.wrapping_mul(1_000_003).wrapping_add(i as u64);
        let llr = make_block_llrs(&code, mode, opts.block_stages, opts.ebn0_db, seed);
        let want = oracle.decode_stream(&llr)?;
        pool.push(Workload { llr, want });
    }
    drop(oracle);
    let t0 = Instant::now();
    let mut tallies: Vec<Result<WorkerTally>> = Vec::with_capacity(opts.sessions);
    let mut worker_panics = 0u64;
    std::thread::scope(|scope| {
        let pool = &pool;
        let mut handles = Vec::with_capacity(opts.sessions);
        for w in 0..opts.sessions {
            let spawned = std::thread::Builder::new()
                .stack_size(WORKER_STACK)
                .spawn_scoped(scope, move || run_worker(addr, builder, opts, pool, w));
            match spawned {
                Ok(h) => handles.push(h),
                // out of threads: count the worker's blocks as failures
                // rather than aborting the whole soak
                Err(_) => worker_panics += 1,
            }
        }
        for h in handles {
            match h.join() {
                Ok(t) => tallies.push(t),
                // a panicked worker is a harness failure, not a process
                // abort: its blocks count as failures and check() fails
                Err(_) => worker_panics += 1,
            }
        }
    });
    let elapsed_s = t0.elapsed().as_secs_f64();
    let mut blocks = 0u64;
    let mut shed_retries = 0u64;
    let mut failures = worker_panics * opts.blocks_per_session as u64;
    let mut mismatches = 0u64;
    let mut payload_bits = 0u64;
    let mut latencies_ms = Vec::new();
    for t in tallies {
        let t = t?;
        blocks += t.blocks;
        shed_retries += t.shed_retries;
        failures += t.failures;
        mismatches += t.mismatches;
        payload_bits += t.payload_bits;
        latencies_ms.extend(t.latencies_ms);
    }
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(LoadgenReport {
        transport: opts.transport.name().to_string(),
        sessions: opts.sessions,
        blocks,
        shed_retries,
        failures,
        worker_panics,
        mismatches,
        payload_bits,
        elapsed_s,
        aggregate_mbps: payload_bits as f64 / elapsed_s.max(1e-9) / 1e6,
        p50_ms: percentile(&latencies_ms, 50.0),
        p99_ms: percentile(&latencies_ms, 99.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_stage_accounting_per_mode() {
        let code = registry::paper_code();
        for mode in
            [TerminationMode::Flushed, TerminationMode::TailBiting, TerminationMode::Truncated]
        {
            let llr = make_block_llrs(&code, mode, 64, 6.0, 7);
            assert_eq!(llr.len(), 64 * code.beta(), "{mode:?}");
        }
    }

    #[test]
    fn report_check_enforces_bounds() {
        let mut r = LoadgenReport {
            transport: "tcp".into(),
            sessions: 2,
            blocks: 4,
            shed_retries: 1,
            failures: 0,
            worker_panics: 0,
            mismatches: 0,
            payload_bits: 1024,
            elapsed_s: 0.5,
            aggregate_mbps: 10.0,
            p50_ms: 1.0,
            p99_ms: 5.0,
        };
        r.check(None, None).unwrap();
        r.check(Some(10.0), Some(1.0)).unwrap();
        assert!(r.check(Some(1.0), None).is_err(), "p99 bound");
        assert!(r.check(None, Some(100.0)).is_err(), "throughput bound");
        r.mismatches = 1;
        assert!(r.check(None, None).is_err(), "mismatches fail the soak");
        r.mismatches = 0;
        r.worker_panics = 1;
        let e = r.check(None, None).unwrap_err();
        assert!(e.to_string().contains("panicked"), "{e}");
        let j = r.to_json().to_string_pretty();
        assert!(j.contains("aggregate_mbps"));
        assert!(j.contains("worker_panics"));
    }

    #[test]
    fn retries_record_one_latency_sample_per_success() {
        // two sheds then success: one sample (the successful attempt's
        // own latency), two counted retries
        let mut tally = WorkerTally::default();
        let mut calls = 0;
        let got = decode_with_retries(10, &mut tally, || {
            calls += 1;
            if calls <= 2 {
                Err(Error::net("block shed: shard queues at depth 9"))
            } else {
                Ok((vec![1, 0, 1], Duration::from_millis(3)))
            }
        });
        assert_eq!(got, Some(vec![1, 0, 1]));
        assert_eq!(tally.latencies_ms.len(), 1, "only the successful attempt is sampled");
        assert!((tally.latencies_ms[0] - 3.0).abs() < 1e-9);
        assert_eq!(tally.shed_retries, 2);

        // retry budget exhausted: no block, no samples
        let mut tally = WorkerTally::default();
        let got = decode_with_retries(1, &mut tally, || Err(Error::net("block shed: cap")));
        assert_eq!(got, None);
        assert!(tally.latencies_ms.is_empty());
        assert_eq!(tally.shed_retries, 1);

        // hard errors never retry and never sample
        let mut tally = WorkerTally::default();
        let got = decode_with_retries(10, &mut tally, || Err(Error::net("connection reset")));
        assert_eq!(got, None);
        assert!(tally.latencies_ms.is_empty());
        assert_eq!(tally.shed_retries, 0);
    }

    #[test]
    fn bad_geometry_rejected() {
        let b = crate::api::DecoderBuilder::new().tile_dims(64, 32, 32);
        let opts = LoadgenOptions { block_stages: 100, ..LoadgenOptions::default() };
        assert!(run("127.0.0.1:1", &b, &opts).is_err());
    }
}
