//! Session lifecycle bookkeeping for the socket front-end: one table
//! owns the concurrent-session cap (TCP connections + live UDP flows
//! count against the same cap) and the idle-eviction clock for UDP
//! flows. TCP idle eviction is the reactor's per-connection liveness
//! clock instead (each tick compares the last read's timestamp against
//! the same timeout — see `net::tcp`), so the table only tracks TCP
//! connections as a count.
//!
//! The table is pure bookkeeping: metrics counters are incremented by
//! the transport loops, which know *why* a session came or went.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::fault::{self, FaultMap};

/// Key of one UDP flow: peer address + client-chosen flow id.
pub type FlowKey = (SocketAddr, u64);

/// Outcome of observing a datagram for a flow.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlowTouch {
    /// First datagram of a new flow; it was admitted.
    New,
    /// The flow is already live; its idle clock was reset.
    Known,
    /// A new flow could not be admitted: the session cap is reached.
    AtCap,
}

struct Inner {
    tcp_active: usize,
    flows: HashMap<FlowKey, Instant>,
}

/// Shared session table (one per [`super::Server`]).
pub struct SessionTable {
    max_sessions: usize,
    idle_timeout: Duration,
    faults: Arc<FaultMap>,
    inner: Mutex<Inner>,
}

impl SessionTable {
    pub fn new(max_sessions: usize, idle_timeout: Duration) -> SessionTable {
        SessionTable::with_faults(max_sessions, idle_timeout, Arc::new(FaultMap::default()))
    }

    /// A table sharing the pipeline's failpoint map: the `net.admit`
    /// site forces admission refusals (as if at cap) for chaos tests.
    pub fn with_faults(
        max_sessions: usize,
        idle_timeout: Duration,
        faults: Arc<FaultMap>,
    ) -> SessionTable {
        SessionTable {
            max_sessions: max_sessions.max(1),
            idle_timeout,
            faults,
            inner: Mutex::new(Inner { tcp_active: 0, flows: HashMap::new() }),
        }
    }

    /// The idle timeout sessions are evicted after.
    pub fn idle_timeout(&self) -> Duration {
        self.idle_timeout
    }

    /// Live sessions right now (TCP connections + UDP flows).
    pub fn active(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.tcp_active + g.flows.len()
    }

    /// Try to admit one TCP session; `false` when the cap is reached
    /// (or the `net.admit` failpoint fires).
    pub fn admit_tcp(&self) -> bool {
        if self.faults.fire(fault::site::NET_ADMIT) {
            return false;
        }
        let mut g = self.inner.lock().unwrap();
        if g.tcp_active + g.flows.len() >= self.max_sessions {
            return false;
        }
        g.tcp_active += 1;
        true
    }

    /// Release one admitted TCP session.
    pub fn release_tcp(&self) {
        let mut g = self.inner.lock().unwrap();
        debug_assert!(g.tcp_active > 0);
        g.tcp_active = g.tcp_active.saturating_sub(1);
    }

    /// Observe a datagram for `key` at time `now`: admits new flows
    /// against the session cap and resets the idle clock of known ones.
    pub fn touch_flow(&self, key: FlowKey, now: Instant) -> FlowTouch {
        let mut g = self.inner.lock().unwrap();
        if let Some(last) = g.flows.get_mut(&key) {
            *last = now;
            return FlowTouch::Known;
        }
        if self.faults.fire(fault::site::NET_ADMIT)
            || g.tcp_active + g.flows.len() >= self.max_sessions
        {
            return FlowTouch::AtCap;
        }
        g.flows.insert(key, now);
        FlowTouch::New
    }

    /// Drop a flow explicitly (protocol error); `true` if it was live.
    pub fn remove_flow(&self, key: &FlowKey) -> bool {
        self.inner.lock().unwrap().flows.remove(key).is_some()
    }

    /// Evict every flow idle for longer than the timeout; returns how
    /// many were evicted.
    pub fn sweep_flows(&self, now: Instant) -> usize {
        let mut g = self.inner.lock().unwrap();
        let timeout = self.idle_timeout;
        let before = g.flows.len();
        g.flows.retain(|_, last| now.duration_since(*last) < timeout);
        before - g.flows.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(port: u16, flow: u64) -> FlowKey {
        (SocketAddr::from(([127, 0, 0, 1], port)), flow)
    }

    #[test]
    fn tcp_cap_is_enforced() {
        let t = SessionTable::new(2, Duration::from_secs(1));
        assert!(t.admit_tcp());
        assert!(t.admit_tcp());
        assert!(!t.admit_tcp(), "third admission must hit the cap");
        t.release_tcp();
        assert!(t.admit_tcp(), "released slot is reusable");
        assert_eq!(t.active(), 2);
    }

    #[test]
    fn flows_share_the_cap_with_tcp() {
        let t = SessionTable::new(2, Duration::from_secs(1));
        let now = Instant::now();
        assert!(t.admit_tcp());
        assert_eq!(t.touch_flow(key(9000, 1), now), FlowTouch::New);
        assert_eq!(t.touch_flow(key(9000, 2), now), FlowTouch::AtCap);
        assert_eq!(t.touch_flow(key(9000, 1), now), FlowTouch::Known, "known flows never shed");
        assert_eq!(t.active(), 2);
    }

    #[test]
    fn sweep_evicts_only_idle_flows() {
        let t = SessionTable::new(8, Duration::from_millis(50));
        let t0 = Instant::now();
        t.touch_flow(key(9000, 1), t0);
        t.touch_flow(key(9001, 1), t0 + Duration::from_millis(40));
        assert_eq!(t.sweep_flows(t0 + Duration::from_millis(60)), 1);
        assert_eq!(t.active(), 1, "the fresh flow survives");
        assert_eq!(t.sweep_flows(t0 + Duration::from_millis(200)), 1);
        assert_eq!(t.active(), 0);
    }

    #[test]
    fn remove_flow_reports_liveness() {
        let t = SessionTable::new(8, Duration::from_secs(1));
        t.touch_flow(key(9000, 7), Instant::now());
        assert!(t.remove_flow(&key(9000, 7)));
        assert!(!t.remove_flow(&key(9000, 7)));
    }

    #[test]
    #[cfg(feature = "failpoints")]
    fn net_admit_failpoint_forces_refusal() {
        let faults = Arc::new(FaultMap::parse("net.admit=hit:1").unwrap());
        let t = SessionTable::with_faults(8, Duration::from_secs(1), faults);
        assert!(!t.admit_tcp(), "first admission is the injected refusal");
        assert!(t.admit_tcp(), "hit:1 fires exactly once");
        // known flows are exempt from the admission site
        let now = Instant::now();
        assert_eq!(t.touch_flow(key(9000, 1), now), FlowTouch::New);
        assert_eq!(t.touch_flow(key(9000, 1), now), FlowTouch::Known);
    }
}
