//! UDP transport of the socket front-end: one datagram is one
//! self-contained block (built for tail-biting block traffic, where
//! every block is decodable on its own). A *flow* — peer address +
//! client-chosen flow id — is the session-lifetime unit: new flows are
//! admitted against the same session cap as TCP connections, idle
//! flows are evicted by a periodic sweep, and blocks arriving while
//! the shard queues are saturated are shed individually with a typed
//! SHED reply (`net.blocks_shed`).
//!
//! The loop is single-threaded by design: each datagram decodes
//! synchronously through `Coordinator::decode_stream_blocking`, which
//! already fans the block's frames out across the engine shards, so a
//! second layer of socket-side threading would only add reordering.
//! One block must fit in one datagram (~64 KiB), which bounds block
//! size at roughly 8k LLRs — datagram-sized blocks are the use case;
//! longer streams belong on TCP.

use std::net::{ToSocketAddrs, UdpSocket};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::defaults;
use crate::error::{Error, Result, ResultExt};

use super::protocol::{udp_status, UdpBlock, UdpReply};
use super::session_table::FlowTouch;
use super::udp_batch::{DatagramTx, ReplyBatch, SysTx};
use super::ServerCtx;

/// Maximum UDP datagram we read or write.
const MAX_DATAGRAM: usize = 65536;

/// How long a client waits for a reply datagram.
const CLIENT_RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// With replies pending in the batch, the serving loop shortens its
/// read timeout to this: just long enough to notice a back-to-back
/// request datagram, so batching adds at most ~1 ms to an isolated
/// reply while a busy socket still aggregates whole batches.
const BATCH_DRAIN_TIMEOUT: Duration = Duration::from_millis(1);

/// The flow sweep period for a given idle timeout: often enough that
/// eviction lag stays well under the timeout, bounded below so tiny
/// test timeouts don't spin the loop.
fn sweep_interval(idle_timeout: Duration) -> Duration {
    (idle_timeout / 2).min(Duration::from_millis(250)).max(Duration::from_millis(10))
}

fn reply<T: DatagramTx>(
    batch: &mut ReplyBatch<'_, T>,
    peer: std::net::SocketAddr,
    r: UdpReply,
) {
    // byte accounting happens inside the batch, at actual-send time
    batch.push(peer, r.encode());
}

/// UDP serving loop (one per server). The socket read timeout doubles
/// as the sweep tick and the shutdown poll interval; while replies sit
/// in the send batch it shrinks to [`BATCH_DRAIN_TIMEOUT`] so the
/// batch flushes as soon as the socket has nothing more to drain.
pub(crate) fn run_udp(socket: UdpSocket, ctx: Arc<ServerCtx>) {
    let sweep = sweep_interval(ctx.table.idle_timeout());
    let tx = SysTx(&socket);
    let mut batch = ReplyBatch::new(&tx, ctx.net.udp_batch, &ctx.metrics.net);
    let _ = socket.set_read_timeout(Some(sweep));
    let mut timeout = sweep;
    let mut buf = vec![0u8; MAX_DATAGRAM];
    let mut last_sweep = Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            batch.flush();
            return;
        }
        let want = if batch.is_empty() { sweep } else { BATCH_DRAIN_TIMEOUT };
        if want != timeout {
            let _ = socket.set_read_timeout(Some(want));
            timeout = want;
        }
        match socket.recv_from(&mut buf) {
            Ok((n, peer)) => {
                ctx.metrics.net.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                // an undecodable header has no flow/seq to echo: drop
                if let Ok(block) = UdpBlock::decode(&buf[..n]) {
                    handle_datagram(&mut batch, &ctx, peer, block);
                }
            }
            // timeout / transient error: the socket is drained — flush
            // pending replies, then fall through to the sweep
            Err(_) => batch.flush(),
        }
        let now = Instant::now();
        if now.duration_since(last_sweep) >= sweep {
            let evicted = ctx.table.sweep_flows(now);
            if evicted > 0 {
                ctx.metrics.net.sessions_evicted.fetch_add(evicted as u64, Ordering::Relaxed);
            }
            last_sweep = now;
        }
    }
}

fn handle_datagram<T: DatagramTx>(
    batch: &mut ReplyBatch<'_, T>,
    ctx: &Arc<ServerCtx>,
    peer: std::net::SocketAddr,
    block: UdpBlock,
) {
    let key = (peer, block.flow);
    let (flow, seq) = (block.flow, block.seq);
    match ctx.table.touch_flow(key, Instant::now()) {
        FlowTouch::AtCap => {
            ctx.metrics.net.sessions_shed.fetch_add(1, Ordering::Relaxed);
            let detail = format!("session cap {} reached", ctx.net.max_sessions);
            let r = UdpReply { flow, seq, status: udp_status::SHED, body: detail.into_bytes() };
            reply(batch, peer, r);
            return;
        }
        FlowTouch::New => {
            ctx.metrics.net.sessions_accepted.fetch_add(1, Ordering::Relaxed);
        }
        FlowTouch::Known => {}
    }
    // per-block load shed: the flow stays admitted, this block is
    // dropped (the client retries once the queues drain)
    if ctx.queues_saturated() {
        ctx.metrics.net.blocks_shed.fetch_add(1, Ordering::Relaxed);
        let detail = format!("shard queues at depth {}", ctx.metrics.queue_depth_total());
        let r = UdpReply { flow, seq, status: udp_status::SHED, body: detail.into_bytes() };
        reply(batch, peer, r);
        return;
    }
    let t0 = Instant::now();
    match ctx.coord.decode_stream_blocking(&block.llr) {
        Ok(bits) => {
            ctx.metrics.record_net_block(t0.elapsed());
            reply(batch, peer, UdpReply { flow, seq, status: udp_status::OK, body: bits });
        }
        Err(e) if e.is_retryable() => {
            // a transient pipeline fault (the block's shard panicked
            // and is restarting): shed this block only — the flow stays
            // admitted and the client's SHED handling resends it
            // against the restarted shard
            ctx.metrics.net.blocks_shed.fetch_add(1, Ordering::Relaxed);
            let r = UdpReply { flow, seq, status: udp_status::SHED, body: e.to_string().into_bytes() };
            reply(batch, peer, r);
        }
        Err(e) => {
            // a block the pipeline rejects (bad length, partial
            // tail-biting tile) poisons the flow: evict it so the
            // lifecycle mirrors a dirty TCP disconnect
            if ctx.table.remove_flow(&key) {
                ctx.metrics.net.sessions_evicted.fetch_add(1, Ordering::Relaxed);
            }
            let r = UdpReply {
                flow,
                seq,
                status: udp_status::ERR,
                body: e.to_string().into_bytes(),
            };
            reply(batch, peer, r);
        }
    }
}

/// Datagram transport a [`UdpClient`] drives. The real implementation
/// is [`UdpSocket`]; tests substitute lossy/reordering shims to
/// exercise the ack-window retransmission path deterministically.
pub trait DatagramSocket {
    /// Send one datagram to the connected peer.
    fn send(&self, buf: &[u8]) -> Result<()>;
    /// Receive one datagram, or `None` once `timeout` elapses.
    fn recv_timeout(&self, buf: &mut [u8], timeout: Duration) -> Result<Option<usize>>;
}

impl DatagramSocket for UdpSocket {
    fn send(&self, buf: &[u8]) -> Result<()> {
        UdpSocket::send(self, buf).or_net("sending block datagram")?;
        Ok(())
    }

    fn recv_timeout(&self, buf: &mut [u8], timeout: Duration) -> Result<Option<usize>> {
        self.set_read_timeout(Some(timeout)).or_net("setting read timeout")?;
        match UdpSocket::recv(self, buf) {
            Ok(n) => Ok(Some(n)),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                Ok(None)
            }
            Err(e) => Err(Error::net(format!("receiving block reply: {e}"))),
        }
    }
}

/// Tunables of [`UdpClient::decode_blocks`] pipelining.
#[derive(Clone, Debug)]
pub struct UdpPipelineOptions {
    /// Blocks in flight (sent, not yet acked) at once.
    pub window: usize,
    /// Silence on the socket for this long retransmits the oldest
    /// un-acked block.
    pub ack_timeout: Duration,
    /// Give up on the whole run after this long.
    pub overall_timeout: Duration,
}

impl Default for UdpPipelineOptions {
    fn default() -> Self {
        UdpPipelineOptions {
            window: defaults::NET_UDP_WINDOW,
            ack_timeout: Duration::from_millis(250),
            overall_timeout: Duration::from_secs(60),
        }
    }
}

/// Counters one [`UdpClient::decode_blocks`] run accumulates.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct UdpRunStats {
    /// Blocks submitted.
    pub blocks: u64,
    /// First OK reply per block (equals `blocks` on success).
    pub acks: u64,
    /// Timeout-driven resends of un-acked blocks.
    pub retransmits: u64,
    /// Replies for blocks that were already acked (duplicated or very
    /// late datagrams).
    pub duplicate_replies: u64,
    /// SHED replies answered with an immediate resend.
    pub shed_retries: u64,
}

/// The result of a pipelined [`UdpClient::decode_blocks`] run.
#[derive(Clone, Debug)]
pub struct UdpRun {
    /// Decoded payload bits, in submission order.
    pub blocks: Vec<Vec<u8>>,
    pub stats: UdpRunStats,
    /// Per block: first send of the block to its OK reply.
    pub latencies: Vec<Duration>,
}

/// A UDP decode flow over any [`DatagramSocket`].
/// [`decode_block`](UdpClient::decode_block) is the stop-and-wait
/// path (one datagram out, block for its reply);
/// [`decode_blocks`](UdpClient::decode_blocks) pipelines many blocks
/// behind a small ack window with retransmission, which is what makes
/// high-session-count UDP soaks runnable over lossy paths.
pub struct UdpClient<S: DatagramSocket = UdpSocket> {
    socket: S,
    flow: u64,
    seq: u32,
}

impl UdpClient<UdpSocket> {
    /// Bind an ephemeral local socket and direct it at `server` as flow
    /// `flow`. No handshake happens — the flow is admitted (or shed)
    /// when its first block arrives.
    pub fn connect(server: impl ToSocketAddrs, flow: u64) -> Result<UdpClient> {
        let socket = UdpSocket::bind(("0.0.0.0", 0)).or_net("binding udp client socket")?;
        socket.connect(server).or_net("directing udp client at server")?;
        Ok(UdpClient { socket, flow, seq: 0 })
    }
}

/// Per-block send state of one pipelined run.
struct InFlight {
    wire: Vec<u8>,
    first_sent: Option<Instant>,
    last_sent: Option<Instant>,
    done: bool,
}

impl<S: DatagramSocket> UdpClient<S> {
    /// Drive flow `flow` over a caller-supplied transport (tests inject
    /// lossy shims here).
    pub fn with_socket(socket: S, flow: u64) -> UdpClient<S> {
        UdpClient { socket, flow, seq: 0 }
    }

    /// The flow id this client sends under.
    pub fn flow(&self) -> u64 {
        self.flow
    }

    /// Decode one block: returns the decoded payload bits, or a typed
    /// [`Error::Net`] when the block was shed or rejected.
    pub fn decode_block(&mut self, llr: &[f32]) -> Result<Vec<u8>> {
        let seq = self.seq;
        self.seq = self.seq.wrapping_add(1);
        let wire = UdpBlock { flow: self.flow, seq, llr: llr.to_vec() }.encode();
        if wire.len() > MAX_DATAGRAM {
            return Err(Error::net(format!(
                "block of {} LLRs does not fit one datagram (use the TCP transport)",
                llr.len()
            )));
        }
        self.socket.send(&wire)?;
        let mut buf = vec![0u8; MAX_DATAGRAM];
        loop {
            let n = match self.socket.recv_timeout(&mut buf, CLIENT_RECV_TIMEOUT)? {
                Some(n) => n,
                None => return Err(Error::net("timed out waiting for the block reply")),
            };
            let r = UdpReply::decode(&buf[..n])?;
            if r.flow != self.flow || r.seq != seq {
                continue; // stale reply from an earlier block
            }
            return match r.status {
                udp_status::OK => Ok(r.body),
                udp_status::SHED => Err(Error::net(format!(
                    "block shed: {}",
                    String::from_utf8_lossy(&r.body)
                ))),
                _ => Err(Error::net(format!(
                    "server error: {}",
                    String::from_utf8_lossy(&r.body)
                ))),
            };
        }
    }

    /// Decode many blocks pipelined behind an ack window: up to
    /// `opts.window` blocks are in flight at once; an un-acked block is
    /// retransmitted after `opts.ack_timeout` of socket silence, a SHED
    /// reply is resent immediately (the shed is per block — the flow
    /// stays admitted), and a reply for an already-acked block only
    /// bumps `duplicate_replies`. The server stays stateless: every
    /// datagram is a self-contained block, so loss, duplication and
    /// reordering are all safe to absorb client-side.
    ///
    /// Fails on an ERR reply (the server evicted the flow) or once
    /// `opts.overall_timeout` elapses.
    pub fn decode_blocks(&mut self, blocks: &[Vec<f32>], opts: &UdpPipelineOptions) -> Result<UdpRun> {
        let window = opts.window.max(1);
        let base = self.seq;
        self.seq = self.seq.wrapping_add(blocks.len() as u32);
        let mut pend = Vec::with_capacity(blocks.len());
        for (i, llr) in blocks.iter().enumerate() {
            let seq = base.wrapping_add(i as u32);
            let wire = UdpBlock { flow: self.flow, seq, llr: llr.clone() }.encode();
            if wire.len() > MAX_DATAGRAM {
                return Err(Error::net(format!(
                    "block of {} LLRs does not fit one datagram (use the TCP transport)",
                    llr.len()
                )));
            }
            pend.push(InFlight { wire, first_sent: None, last_sent: None, done: false });
        }
        let mut out: Vec<Option<Vec<u8>>> = vec![None; pend.len()];
        let mut latencies = vec![Duration::ZERO; pend.len()];
        let mut stats = UdpRunStats { blocks: pend.len() as u64, ..UdpRunStats::default() };
        let mut next_unsent = 0usize;
        let mut done = 0usize;
        let t_start = Instant::now();
        let mut buf = vec![0u8; MAX_DATAGRAM];
        while done < pend.len() {
            if t_start.elapsed() > opts.overall_timeout {
                return Err(Error::net(format!(
                    "timed out with {} of {} blocks un-acked",
                    pend.len() - done,
                    pend.len()
                )));
            }
            // keep the window full
            let mut in_flight = pend.iter().filter(|p| p.first_sent.is_some() && !p.done).count();
            while next_unsent < pend.len() && in_flight < window {
                self.socket.send(&pend[next_unsent].wire)?;
                let now = Instant::now();
                pend[next_unsent].first_sent = Some(now);
                pend[next_unsent].last_sent = Some(now);
                next_unsent += 1;
                in_flight += 1;
            }
            match self.socket.recv_timeout(&mut buf, opts.ack_timeout)? {
                Some(n) => {
                    let r = UdpReply::decode(&buf[..n])?;
                    if r.flow != self.flow {
                        continue;
                    }
                    let idx = r.seq.wrapping_sub(base) as usize;
                    if idx >= pend.len() || pend[idx].first_sent.is_none() {
                        continue; // stale reply from an earlier run
                    }
                    if pend[idx].done {
                        stats.duplicate_replies += 1;
                        continue;
                    }
                    match r.status {
                        udp_status::OK => {
                            pend[idx].done = true;
                            done += 1;
                            stats.acks += 1;
                            latencies[idx] = pend[idx].first_sent.unwrap().elapsed();
                            out[idx] = Some(r.body);
                        }
                        udp_status::SHED => {
                            stats.shed_retries += 1;
                            self.socket.send(&pend[idx].wire)?;
                            pend[idx].last_sent = Some(Instant::now());
                        }
                        _ => {
                            return Err(Error::net(format!(
                                "server error: {}",
                                String::from_utf8_lossy(&r.body)
                            )))
                        }
                    }
                }
                None => {
                    // socket silence: the oldest un-acked block (or its
                    // reply) was probably lost — resend just that one
                    if let Some(p) = pend
                        .iter_mut()
                        .filter(|p| p.first_sent.is_some() && !p.done)
                        .min_by_key(|p| p.last_sent.unwrap())
                    {
                        self.socket.send(&p.wire)?;
                        p.last_sent = Some(Instant::now());
                        stats.retransmits += 1;
                    }
                }
            }
        }
        let blocks = out.into_iter().map(|b| b.expect("acked block has bits")).collect();
        Ok(UdpRun { blocks, stats, latencies })
    }
}
