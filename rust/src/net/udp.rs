//! UDP transport of the socket front-end: one datagram is one
//! self-contained block (built for tail-biting block traffic, where
//! every block is decodable on its own). A *flow* — peer address +
//! client-chosen flow id — is the session-lifetime unit: new flows are
//! admitted against the same session cap as TCP connections, idle
//! flows are evicted by a periodic sweep, and blocks arriving while
//! the shard queues are saturated are shed individually with a typed
//! SHED reply (`net.blocks_shed`).
//!
//! The loop is single-threaded by design: each datagram decodes
//! synchronously through `Coordinator::decode_stream_blocking`, which
//! already fans the block's frames out across the engine shards, so a
//! second layer of socket-side threading would only add reordering.
//! One block must fit in one datagram (~64 KiB), which bounds block
//! size at roughly 8k LLRs — datagram-sized blocks are the use case;
//! longer streams belong on TCP.

use std::net::{ToSocketAddrs, UdpSocket};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result, ResultExt};

use super::protocol::{udp_status, UdpBlock, UdpReply};
use super::session_table::FlowTouch;
use super::ServerCtx;

/// Maximum UDP datagram we read or write.
const MAX_DATAGRAM: usize = 65536;

/// How long a client waits for a reply datagram.
const CLIENT_RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// The flow sweep period for a given idle timeout: often enough that
/// eviction lag stays well under the timeout, bounded below so tiny
/// test timeouts don't spin the loop.
fn sweep_interval(idle_timeout: Duration) -> Duration {
    (idle_timeout / 2).min(Duration::from_millis(250)).max(Duration::from_millis(10))
}

fn reply(socket: &UdpSocket, ctx: &ServerCtx, peer: std::net::SocketAddr, r: UdpReply) {
    let wire = r.encode();
    if socket.send_to(&wire, peer).is_ok() {
        ctx.metrics.net.bytes_out.fetch_add(wire.len() as u64, Ordering::Relaxed);
    }
}

/// UDP serving loop (one per server). The socket read timeout doubles
/// as the sweep tick and the shutdown poll interval.
pub(crate) fn run_udp(socket: UdpSocket, ctx: Arc<ServerCtx>) {
    let sweep = sweep_interval(ctx.table.idle_timeout());
    let _ = socket.set_read_timeout(Some(sweep));
    let mut buf = vec![0u8; MAX_DATAGRAM];
    let mut last_sweep = Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match socket.recv_from(&mut buf) {
            Ok((n, peer)) => {
                ctx.metrics.net.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                // an undecodable header has no flow/seq to echo: drop
                if let Ok(block) = UdpBlock::decode(&buf[..n]) {
                    handle_datagram(&socket, &ctx, peer, block);
                }
            }
            // timeout: fall through to the sweep; other errors are
            // transient on a datagram socket
            Err(_) => {}
        }
        let now = Instant::now();
        if now.duration_since(last_sweep) >= sweep {
            let evicted = ctx.table.sweep_flows(now);
            if evicted > 0 {
                ctx.metrics.net.sessions_evicted.fetch_add(evicted as u64, Ordering::Relaxed);
            }
            last_sweep = now;
        }
    }
}

fn handle_datagram(
    socket: &UdpSocket,
    ctx: &Arc<ServerCtx>,
    peer: std::net::SocketAddr,
    block: UdpBlock,
) {
    let key = (peer, block.flow);
    let (flow, seq) = (block.flow, block.seq);
    match ctx.table.touch_flow(key, Instant::now()) {
        FlowTouch::AtCap => {
            ctx.metrics.net.sessions_shed.fetch_add(1, Ordering::Relaxed);
            let detail = format!("session cap {} reached", ctx.net.max_sessions);
            let r = UdpReply { flow, seq, status: udp_status::SHED, body: detail.into_bytes() };
            reply(socket, ctx, peer, r);
            return;
        }
        FlowTouch::New => {
            ctx.metrics.net.sessions_accepted.fetch_add(1, Ordering::Relaxed);
        }
        FlowTouch::Known => {}
    }
    // per-block load shed: the flow stays admitted, this block is
    // dropped (the client retries once the queues drain)
    if ctx.queues_saturated() {
        ctx.metrics.net.blocks_shed.fetch_add(1, Ordering::Relaxed);
        let detail = format!("shard queues at depth {}", ctx.metrics.queue_depth_total());
        let r = UdpReply { flow, seq, status: udp_status::SHED, body: detail.into_bytes() };
        reply(socket, ctx, peer, r);
        return;
    }
    let t0 = Instant::now();
    match ctx.coord.decode_stream_blocking(&block.llr) {
        Ok(bits) => {
            ctx.metrics.record_net_block(t0.elapsed());
            reply(socket, ctx, peer, UdpReply { flow, seq, status: udp_status::OK, body: bits });
        }
        Err(e) => {
            // a block the pipeline rejects (bad length, partial
            // tail-biting tile) poisons the flow: evict it so the
            // lifecycle mirrors a dirty TCP disconnect
            if ctx.table.remove_flow(&key) {
                ctx.metrics.net.sessions_evicted.fetch_add(1, Ordering::Relaxed);
            }
            let r = UdpReply {
                flow,
                seq,
                status: udp_status::ERR,
                body: e.to_string().into_bytes(),
            };
            reply(socket, ctx, peer, r);
        }
    }
}

/// A UDP decode flow. Each [`decode_block`](UdpClient::decode_block)
/// sends one block datagram and blocks for its reply; stale replies
/// (earlier sequence numbers) are discarded.
pub struct UdpClient {
    socket: UdpSocket,
    flow: u64,
    seq: u32,
}

impl UdpClient {
    /// Bind an ephemeral local socket and direct it at `server` as flow
    /// `flow`. No handshake happens — the flow is admitted (or shed)
    /// when its first block arrives.
    pub fn connect(server: impl ToSocketAddrs, flow: u64) -> Result<UdpClient> {
        let socket = UdpSocket::bind(("0.0.0.0", 0)).or_net("binding udp client socket")?;
        socket.connect(server).or_net("directing udp client at server")?;
        socket.set_read_timeout(Some(CLIENT_RECV_TIMEOUT)).or_net("setting read timeout")?;
        Ok(UdpClient { socket, flow, seq: 0 })
    }

    /// The flow id this client sends under.
    pub fn flow(&self) -> u64 {
        self.flow
    }

    /// Decode one block: returns the decoded payload bits, or a typed
    /// [`Error::Net`] when the block was shed or rejected.
    pub fn decode_block(&mut self, llr: &[f32]) -> Result<Vec<u8>> {
        let seq = self.seq;
        self.seq = self.seq.wrapping_add(1);
        let wire = UdpBlock { flow: self.flow, seq, llr: llr.to_vec() }.encode();
        if wire.len() > MAX_DATAGRAM {
            return Err(Error::net(format!(
                "block of {} LLRs does not fit one datagram (use the TCP transport)",
                llr.len()
            )));
        }
        self.socket.send(&wire).or_net("sending block datagram")?;
        let mut buf = vec![0u8; MAX_DATAGRAM];
        loop {
            let n = self.socket.recv(&mut buf).map_err(|e| {
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) {
                    Error::net("timed out waiting for the block reply")
                } else {
                    Error::net(format!("receiving block reply: {e}"))
                }
            })?;
            let r = UdpReply::decode(&buf[..n])?;
            if r.flow != self.flow || r.seq != seq {
                continue; // stale reply from an earlier block
            }
            return match r.status {
                udp_status::OK => Ok(r.body),
                udp_status::SHED => Err(Error::net(format!(
                    "block shed: {}",
                    String::from_utf8_lossy(&r.body)
                ))),
                _ => Err(Error::net(format!(
                    "server error: {}",
                    String::from_utf8_lossy(&r.body)
                ))),
            };
        }
    }
}
