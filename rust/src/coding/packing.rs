//! Tensor packing specs — Rust mirror of `python/compile/packing.py`
//! (paper Figs 5, 14, 15). The CPU emulation decoders
//! (`viterbi/radix2.rs`, `viterbi/radix4.rs`) execute exactly these
//! specs, so their arithmetic is the same as the AOT artifact's.

use anyhow::{bail, Result};

use super::trellis::Trellis;

/// Static tensor packing of one decoder step (rho trellis stages).
///
/// Field layouts match the python mirror:
/// * `a[o][r][c]` — ±1/0 Theta entries (16x16 per op).
/// * `e[o][r][c]` — which LLR entry feeds B\[r,c\] (or -1).
/// * `cg[o][r][c]` — lambda gather state index (or -1).
/// * `os[o][g][c]` — global right state written by (group, col) (or -1).
/// * `pinv[o][c][sel]` — argmax -> true left-local state.
/// * `src[s]` — (op, group, col) producing state s.
#[derive(Clone, Debug)]
pub struct Packing {
    pub scheme: &'static str,
    pub rho: u32,
    pub gamma: usize,
    pub n_ops: usize,
    pub width: usize,
    pub a: Vec<Vec<Vec<f32>>>,
    pub e: Vec<Vec<Vec<i32>>>,
    pub cg: Vec<Vec<Vec<i32>>>,
    pub os: Vec<Vec<Vec<i32>>>,
    pub pinv: Vec<Vec<Vec<u32>>>,
    pub src: Vec<(usize, usize, usize)>,
}

impl Packing {
    /// The paper's Q metric: tensor ops per trellis stage.
    pub fn ops_per_stage(&self) -> f64 {
        self.n_ops as f64 / self.rho as f64
    }

    pub fn groups_per_col(&self) -> usize {
        16 / self.gamma
    }

    /// Structural invariants (same checks as python `Packing.validate`).
    pub fn validate(&self, n_states: usize) -> Result<()> {
        let mut seen = vec![false; n_states];
        for (o, op) in self.os.iter().enumerate() {
            for (g, row) in op.iter().enumerate() {
                for (c, &s) in row.iter().enumerate() {
                    if s < 0 {
                        continue;
                    }
                    let s = s as usize;
                    if s >= n_states {
                        bail!("OS out of range: {s}");
                    }
                    if seen[s] {
                        bail!("state {s} produced twice");
                    }
                    seen[s] = true;
                    if self.src[s] != (o, g, c) {
                        bail!("src[{s}] inconsistent");
                    }
                }
            }
        }
        if let Some(miss) = seen.iter().position(|&x| !x) {
            bail!("state {miss} never produced");
        }
        for op in &self.cg {
            for row in op {
                for &v in row {
                    if v >= n_states as i32 {
                        bail!("CG out of range: {v}");
                    }
                }
            }
        }
        Ok(())
    }
}

fn zeros3_f(o: usize) -> Vec<Vec<Vec<f32>>> {
    vec![vec![vec![0.0; 16]; 16]; o]
}

fn fill3_i(o: usize, a: usize, b: usize, v: i32) -> Vec<Vec<Vec<i32>>> {
    vec![vec![vec![v; b]; a]; o]
}

/// Theta_f of a butterfly (Eq 17): `[4][beta]` of ±1, row order
/// (i0,j0),(i1,j0),(i0,j1),(i1,j1).
fn theta_butterfly(t: &Trellis, f: u32) -> Vec<Vec<f32>> {
    let beta = t.code().beta();
    let mut rows = Vec::with_capacity(4);
    for j in 0..2u32 {
        for i in 0..2u32 {
            let a = t.superbranch_output(1, f, i, j);
            rows.push((0..beta).map(|b| 1.0 - 2.0 * ((a >> b) & 1) as f32).collect());
        }
    }
    rows
}

/// Fig 5: diagonal 4x4 blocks, butterflies sharing a Theta share a block.
pub fn build_radix2(t: &Trellis) -> Packing {
    let code = t.code();
    let beta = code.beta();
    assert!(beta <= 4, "radix2 packing supports beta <= 4, got {beta}");
    let s_count = code.n_states();
    let nf = t.n_dragonflies(1);

    // bucket butterflies by identical Theta signature, sorted for
    // determinism (mirror of python's sorted(buckets.items()))
    let mut buckets: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
    for f in 0..nf as u32 {
        let sig = t.theta_signature(1, f);
        match buckets.iter_mut().find(|(s, _)| *s == sig) {
            Some((_, fs)) => fs.push(f),
            None => buckets.push((sig, vec![f])),
        }
    }
    buckets.sort_by(|a, b| a.0.cmp(&b.0));

    let mut units: Vec<(usize, Vec<u32>)> = Vec::new(); // (bucket idx, <=4 butterflies)
    for (bi, (_, fs)) in buckets.iter().enumerate() {
        for chunk in fs.chunks(4) {
            units.push((bi, chunk.to_vec()));
        }
    }
    let n_ops = units.len().div_ceil(4);

    let mut a = zeros3_f(n_ops);
    let mut e = fill3_i(n_ops, 16, 16, -1);
    let mut cg = fill3_i(n_ops, 16, 16, -1);
    let mut os = fill3_i(n_ops, 8, 16, -1);
    let pinv = vec![vec![vec![0u32, 1]; 16]; n_ops];
    let mut src = vec![(0usize, 0usize, 0usize); s_count];

    for (u, (bi, fs)) in units.iter().enumerate() {
        let (o, p) = (u / 4, u % 4);
        let theta = theta_butterfly(t, buckets[*bi].1[0]);
        for (r, row) in theta.iter().enumerate() {
            for (cidx, &v) in row.iter().enumerate() {
                a[o][4 * p + r][4 * p + cidx] = v;
            }
        }
        for (cc, &f) in fs.iter().enumerate() {
            let c = 4 * p + cc;
            for ei in 0..beta {
                e[o][4 * p + ei][c] = ei as i32;
            }
            let (i0, i1) = (2 * f as i32, 2 * f as i32 + 1);
            cg[o][4 * p][c] = i0;
            cg[o][4 * p + 1][c] = i1;
            cg[o][4 * p + 2][c] = i0;
            cg[o][4 * p + 3][c] = i1;
            let j0 = t.dragonfly_state(1, f, 1, 0) as usize;
            let j1 = t.dragonfly_state(1, f, 1, 1) as usize;
            os[o][2 * p][c] = j0 as i32;
            os[o][2 * p + 1][c] = j1 as i32;
            src[j0] = (o, 2 * p, c);
            src[j1] = (o, 2 * p + 1, c);
        }
    }

    let pk = Packing {
        scheme: "radix2",
        rho: 1,
        gamma: 2,
        n_ops,
        width: beta,
        a,
        e,
        cg,
        os,
        pinv,
        src,
    };
    pk.validate(s_count).expect("radix2 packing invalid");
    pk
}

/// Fig 14 (use_perm=false) / Fig 15 (use_perm=true).
pub fn build_radix4(t: &Trellis, use_perm: bool) -> Packing {
    let code = t.code();
    let beta = code.beta();
    let s_count = code.n_states();
    let rho = 2u32;
    let gamma = 4usize;
    let w = (rho as usize) * beta;
    let nf = t.n_dragonflies(rho);

    let (rep_of, perm_of, group_of): (Vec<u32>, Vec<Vec<u32>>, Vec<u32>) = if use_perm {
        let (reps, group_of, perm) = t.dragonfly_groups(rho);
        let rep_of = group_of.iter().map(|&g| reps[g as usize]).collect();
        (rep_of, perm, group_of)
    } else {
        (
            (0..nf as u32).collect(),
            vec![(0..gamma as u32).collect(); nf],
            (0..nf as u32).collect(),
        )
    };

    // bucket dragonflies by group
    let n_groups = *group_of.iter().max().unwrap() as usize + 1;
    let mut by_group: Vec<Vec<u32>> = vec![Vec::new(); n_groups];
    for f in 0..nf as u32 {
        by_group[group_of[f as usize] as usize].push(f);
    }

    // assign to (op, col): <=16/W Theta slots and <=16 cols per op
    // (mirror of the python greedy)
    let slots_per_op = 16 / w;
    assert!(slots_per_op >= 1, "super-branch width {w} exceeds the 16x16 op");
    let mut ops: Vec<Vec<(usize, u32)>> = Vec::new();
    let mut op_groups: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<(usize, u32)> = Vec::new();
    let mut cur_groups: Vec<usize> = Vec::new();
    for g in 0..n_groups {
        for &f in &by_group[g] {
            if !cur_groups.contains(&g) {
                if cur_groups.len() == slots_per_op || cur.len() == 16 {
                    ops.push(std::mem::take(&mut cur));
                    op_groups.push(std::mem::take(&mut cur_groups));
                }
                cur_groups.push(g);
            }
            if cur.len() == 16 {
                ops.push(std::mem::take(&mut cur));
                op_groups.push(std::mem::take(&mut cur_groups));
                cur_groups.push(g);
            }
            let slot = cur_groups.iter().position(|&x| x == g).unwrap();
            cur.push((slot, f));
        }
    }
    if !cur.is_empty() {
        ops.push(cur);
        op_groups.push(cur_groups);
    }
    let n_ops = ops.len();

    let mut a = zeros3_f(n_ops);
    let mut e = fill3_i(n_ops, 16, 16, -1);
    let mut cg = fill3_i(n_ops, 16, 16, -1);
    let mut os = fill3_i(n_ops, 4, 16, -1);
    let mut pinv = vec![vec![(0..gamma as u32).collect::<Vec<u32>>(); 16]; n_ops];
    let mut src = vec![(0usize, 0usize, 0usize); s_count];

    for (o, (cols, groups)) in ops.iter().zip(&op_groups).enumerate() {
        for (slot, &g) in groups.iter().enumerate() {
            let rep = if use_perm { rep_of[by_group[g][0] as usize] } else { by_group[g][0] };
            // Theta-hat rows (Eq 36): row 4j+i = +-1 bits of superbranch i->j
            for j in 0..4u32 {
                for i in 0..4u32 {
                    let alpha = t.superbranch_output(rho, rep, i, j);
                    for b in 0..w {
                        a[o][(4 * j + i) as usize][w * slot + b] =
                            1.0 - 2.0 * ((alpha >> b) & 1) as f32;
                    }
                }
            }
        }
        for (c, &(slot, f)) in cols.iter().enumerate() {
            let pi = &perm_of[f as usize];
            let mut pv = vec![0u32; gamma];
            for i in 0..gamma {
                pv[pi[i] as usize] = i as u32;
            }
            for ei in 0..w {
                e[o][w * slot + ei][c] = ei as i32;
            }
            for j in 0..4u32 {
                for i in 0..gamma {
                    // row 4j+i holds rep's branch pinv(i) -> j, whose
                    // lambda is dragonfly f's left state pinv[i]
                    cg[o][(4 * j) as usize + i][c] =
                        t.dragonfly_state(rho, f, 0, pv[i]) as i32;
                }
                let s = t.dragonfly_state(rho, f, rho, j) as usize;
                os[o][j as usize][c] = s as i32;
                src[s] = (o, j as usize, c);
            }
            pinv[o][c] = pv;
        }
    }

    let pk = Packing {
        scheme: if use_perm { "radix4" } else { "radix4_noperm" },
        rho,
        gamma,
        n_ops,
        width: w,
        a,
        e,
        cg,
        os,
        pinv,
        src,
    };
    pk.validate(s_count).expect("radix4 packing invalid");
    pk
}

/// Build by scheme name (matching the python/packing.py entry point).
pub fn build_packing(t: &Trellis, scheme: &str) -> Result<Packing> {
    match scheme {
        "radix2" => Ok(build_radix2(t)),
        "radix4" => Ok(build_radix4(t, true)),
        "radix4_noperm" => Ok(build_radix4(t, false)),
        _ => bail!("unknown packing scheme {scheme:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::poly::Code;

    fn trellis() -> Trellis {
        Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap())
    }

    #[test]
    fn radix2_q_is_2() {
        let pk = build_radix2(&trellis());
        assert_eq!(pk.n_ops, 2);
        assert_eq!(pk.ops_per_stage(), 2.0);
        assert_eq!(pk.width, 2);
    }

    #[test]
    fn radix4_perm_q_is_half() {
        let pk = build_radix4(&trellis(), true);
        assert_eq!(pk.n_ops, 1); // Fig 15: whole trellis in one op
        assert_eq!(pk.ops_per_stage(), 0.5);
        assert_eq!(pk.width, 4);
    }

    #[test]
    fn radix4_noperm_q_is_2() {
        let pk = build_radix4(&trellis(), false);
        assert_eq!(pk.n_ops, 4); // Fig 14
        assert_eq!(pk.ops_per_stage(), 2.0);
    }

    #[test]
    fn a_entries_are_sign_values() {
        for scheme in ["radix2", "radix4", "radix4_noperm"] {
            let pk = build_packing(&trellis(), scheme).unwrap();
            for op in &pk.a {
                for row in op {
                    for &v in row {
                        assert!(v == 0.0 || v == 1.0 || v == -1.0);
                    }
                }
            }
        }
    }

    #[test]
    fn gsm_k5_packs_too() {
        // generality: a different code must still produce a valid packing
        let t = Trellis::new(Code::from_octal(5, &["23", "33"]).unwrap());
        for scheme in ["radix2", "radix4", "radix4_noperm"] {
            let pk = build_packing(&t, scheme).unwrap();
            pk.validate(16).unwrap();
        }
    }
}
