//! The convolutional encoder (paper Fig 1a): the transmitter side of the
//! verification system (Fig 12, steps 1-2) and of every workload
//! generator in the benches.
//!
//! Every [`TerminationMode`] has an encoding entry here
//! (`docs/DECODING-MODES.md` is the guide; the decoder-side counterpart
//! is [`make_frames`](crate::viterbi::tiled::make_frames)):
//!
//! ```
//! use tcvd::coding::{registry, Encoder, TerminationMode};
//!
//! let mut enc = Encoder::new(registry::paper_code()); // (2,1,7) 171/133
//! let bits = [1, 0, 1, 1, 0, 1, 0, 0];
//!
//! // Flushed: k-1 = 6 zero bits appended, encoder returns to state 0.
//! let (coded, n) = enc.encode_terminated(&bits, TerminationMode::Flushed);
//! assert_eq!(n, 8 + 6);           // trellis length *includes* the flush
//! assert_eq!(coded.len(), n * 2); // beta coded bits per trellis stage
//! assert_eq!(enc.state(), 0);
//!
//! // Tail-biting: register pre-loaded with the last k-1 data bits, so
//! // the end state equals the start state — and no flush-bit rate loss.
//! let (coded, n) = enc.encode_terminated(&bits, TerminationMode::TailBiting);
//! assert_eq!((coded.len(), n), (8 * 2, 8));
//!
//! // Truncated: no flush either, but the register just stops mid-air.
//! let (coded, n) = enc.encode_terminated(&bits, TerminationMode::Truncated);
//! assert_eq!((coded.len(), n), (8 * 2, 8));
//! ```

use super::poly::Code;
use super::TerminationMode;
use crate::util::bitvec::BitVec;

/// Stateful convolutional encoder.
#[derive(Clone, Debug)]
pub struct Encoder {
    code: Code,
    state: u32,
}

impl Encoder {
    pub fn new(code: Code) -> Self {
        Encoder { code, state: 0 }
    }

    pub fn code(&self) -> &Code {
        &self.code
    }

    pub fn state(&self) -> u32 {
        self.state
    }

    pub fn reset(&mut self) {
        self.state = 0;
    }

    /// Encode one input bit, returning the beta coded bits
    /// (LSB-polynomial-first).
    #[inline]
    pub fn push(&mut self, u: u8) -> u32 {
        let out = self.code.branch_output(self.state, u as u32);
        self.state = self.code.next_state(self.state, u as u32);
        out
    }

    /// Encode a bit slice into a flat coded-bit vector
    /// (beta bits per input bit, polynomial-0 first).
    pub fn encode(&mut self, bits: &[u8]) -> Vec<u8> {
        let beta = self.code.beta();
        let mut out = Vec::with_capacity(bits.len() * beta);
        for &u in bits {
            let o = self.push(u);
            for b in 0..beta {
                out.push(((o >> b) & 1) as u8);
            }
        }
        out
    }

    /// Encode and append `k - 1` zero flush bits, returning
    /// `(coded bits, flushed length)` where the **flushed length** is
    /// `bits.len() + (k - 1)` — the number of *trellis stages* the coded
    /// stream spans, not the number of information bits. Downstream
    /// frame-length accounting (the tiler's payload alignment, the
    /// survivor-ring sizing in `docs/MEMORY.md`) works in trellis
    /// stages, so it is the flushed length that must be a multiple of
    /// the tile payload. The coded vector always holds `beta` bits per
    /// trellis stage: `coded.len() == flushed_len * beta`.
    ///
    /// Flushing forces the trellis back to state 0, which the decoder
    /// exploits (known end state).
    pub fn encode_flushed(&mut self, bits: &[u8]) -> (Vec<u8>, usize) {
        let flush = vec![0u8; (self.code.k() - 1) as usize];
        let mut all = self.encode(bits);
        all.extend(self.encode(&flush));
        (all, bits.len() + flush.len())
    }

    /// Tail-biting encode: pre-load the shift register with the last
    /// `k - 1` data bits (circularly repeated when the block is shorter
    /// than that) so the encoder's end state **equals its start state**
    /// — the LTE PBCH/PDCCH scheme that avoids the flush-bit rate loss.
    /// Overwrites any prior encoder state. Returns `beta * bits.len()`
    /// coded bits; the decoder side is
    /// [`TerminationMode::TailBiting`].
    ///
    /// # Panics
    /// Panics on an empty block (there is no register content to wrap).
    pub fn encode_tail_biting(&mut self, bits: &[u8]) -> Vec<u8> {
        assert!(!bits.is_empty(), "tail-biting needs at least one data bit");
        let k = self.code.k() as usize;
        let n = bits.len();
        // state = previous k-1 inputs, newest at the MSB: seed it with
        // the block's last k-1 bits (index (n - i) mod n for i = 1..k)
        let mut state = 0u32;
        for i in 1..k {
            let idx = (n - 1) - ((i - 1) % n);
            state |= (bits[idx] as u32) << (k - 1 - i);
        }
        self.state = state;
        let out = self.encode(bits);
        debug_assert_eq!(self.state, state, "tail-biting end state must equal start state");
        out
    }

    /// Truncated encode: reset to state 0 and encode the block with no
    /// flush bits at all. The register ends wherever the data drove it,
    /// so the decoder ([`TerminationMode::Truncated`]) starts traceback
    /// from the best-metric end state instead of a pinned one.
    pub fn encode_truncated(&mut self, bits: &[u8]) -> Vec<u8> {
        self.reset();
        self.encode(bits)
    }

    /// Encode one standalone block under a [`TerminationMode`],
    /// returning `(coded bits, trellis length)`. The trellis length is
    /// the stage count the coded stream spans — `bits.len() + (k - 1)`
    /// for [`Flushed`](TerminationMode::Flushed) (see
    /// [`encode_flushed`](Self::encode_flushed)), `bits.len()` for the
    /// other modes — and is the quantity the decoder's tile payload
    /// must divide. Always starts from a fresh register (tail-biting
    /// pre-loads it, the other modes reset to state 0).
    pub fn encode_terminated(&mut self, bits: &[u8], mode: TerminationMode) -> (Vec<u8>, usize) {
        match mode {
            TerminationMode::Flushed => {
                self.reset();
                self.encode_flushed(bits)
            }
            TerminationMode::TailBiting => (self.encode_tail_biting(bits), bits.len()),
            TerminationMode::Truncated => (self.encode_truncated(bits), bits.len()),
        }
    }

    /// Encode into packed words (the paper's §III input compaction).
    pub fn encode_packed(&mut self, bits: &[u8]) -> BitVec {
        BitVec::from_bits(&self.encode(bits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ccsds() -> Code {
        Code::from_octal(7, &["171", "133"]).unwrap()
    }

    #[test]
    fn matches_python_mirror() {
        // same vector as the python sanity check:
        // encode([1,0,1,1,0,0,0,0,0,0]) -> first 12 coded bits
        let mut e = Encoder::new(ccsds());
        let out = e.encode(&[1, 0, 1, 1, 0, 0, 0, 0, 0, 0]);
        assert_eq!(&out[..12], &[1, 1, 1, 0, 0, 0, 1, 0, 0, 1, 0, 1]);
        assert_eq!(e.state(), 0);
    }

    #[test]
    fn zero_input_zero_output() {
        let mut e = Encoder::new(ccsds());
        assert!(e.encode(&[0; 20]).iter().all(|&b| b == 0));
    }

    #[test]
    fn flush_returns_to_zero() {
        let mut e = Encoder::new(ccsds());
        let (coded, n) = e.encode_flushed(&[1, 1, 0, 1, 0, 1, 1]);
        assert_eq!(e.state(), 0);
        // the returned length is the *flushed* trellis length (data +
        // k-1 flush stages) — the stage count downstream frame-length
        // accounting (tiling alignment, survivor-ring sizing) uses
        assert_eq!(n, 7 + 6);
        assert_eq!(coded.len(), n * e.code().beta(), "beta coded bits per trellis stage");
    }

    #[test]
    fn tail_biting_end_state_equals_start() {
        let e0 = Encoder::new(ccsds());
        for n in [1usize, 3, 5, 6, 7, 40, 129] {
            let mut e = e0.clone();
            let bits = crate::util::rng::Rng::new(n as u64).bits(n);
            let coded = e.encode_tail_biting(&bits);
            assert_eq!(coded.len(), n * 2, "n={n}");
            // re-derive the preload: last k-1 bits, newest at MSB,
            // wrapping circularly for blocks shorter than k-1
            let mut want = 0u32;
            for i in 1..7usize {
                want |= (bits[(n - 1) - ((i - 1) % n)] as u32) << (6 - i);
            }
            assert_eq!(e.state(), want, "n={n}: end state must equal the preloaded start");
        }
    }

    #[test]
    fn tail_biting_matches_plain_encode_from_preload() {
        // same coded bits as a plain encode started in the preloaded state
        let bits = [1u8, 0, 1, 1, 0, 0, 1, 0, 1, 1];
        let mut tb = Encoder::new(ccsds());
        let coded = tb.encode_tail_biting(&bits);
        let start = tb.state(); // == preload, by the invariant above
        let mut plain = Encoder::new(ccsds());
        plain.state = start;
        assert_eq!(plain.encode(&bits), coded);
    }

    #[test]
    fn encode_terminated_lengths_per_mode() {
        let bits = crate::util::rng::Rng::new(9).bits(20);
        let mut e = Encoder::new(ccsds());
        let (c, n) = e.encode_terminated(&bits, TerminationMode::Flushed);
        assert_eq!((n, c.len()), (26, 52));
        assert_eq!(e.state(), 0);
        let (c, n) = e.encode_terminated(&bits, TerminationMode::TailBiting);
        assert_eq!((n, c.len()), (20, 40));
        let (c, n) = e.encode_terminated(&bits, TerminationMode::Truncated);
        assert_eq!((n, c.len()), (20, 40));
        // truncated leaves the register wherever the data drove it
        assert_ne!(e.state(), 0, "these 20 bits do not end in six zeros");
    }

    #[test]
    fn output_length_is_beta_per_bit() {
        let mut e = Encoder::new(ccsds());
        assert_eq!(e.encode(&[1, 0, 1]).len(), 6);
    }

    #[test]
    fn state_evolution_is_shift_register() {
        let mut e = Encoder::new(ccsds());
        e.push(1);
        assert_eq!(e.state(), 0b100000);
        e.push(1);
        assert_eq!(e.state(), 0b110000);
        e.push(0);
        assert_eq!(e.state(), 0b011000);
    }
}
