//! The convolutional encoder (paper Fig 1a): the transmitter side of the
//! verification system (Fig 12, steps 1-2) and of every workload
//! generator in the benches.

use super::poly::Code;
use crate::util::bitvec::BitVec;

/// Stateful convolutional encoder.
#[derive(Clone, Debug)]
pub struct Encoder {
    code: Code,
    state: u32,
}

impl Encoder {
    pub fn new(code: Code) -> Self {
        Encoder { code, state: 0 }
    }

    pub fn code(&self) -> &Code {
        &self.code
    }

    pub fn state(&self) -> u32 {
        self.state
    }

    pub fn reset(&mut self) {
        self.state = 0;
    }

    /// Encode one input bit, returning the beta coded bits
    /// (LSB-polynomial-first).
    #[inline]
    pub fn push(&mut self, u: u8) -> u32 {
        let out = self.code.branch_output(self.state, u as u32);
        self.state = self.code.next_state(self.state, u as u32);
        out
    }

    /// Encode a bit slice into a flat coded-bit vector
    /// (beta bits per input bit, polynomial-0 first).
    pub fn encode(&mut self, bits: &[u8]) -> Vec<u8> {
        let beta = self.code.beta();
        let mut out = Vec::with_capacity(bits.len() * beta);
        for &u in bits {
            let o = self.push(u);
            for b in 0..beta {
                out.push(((o >> b) & 1) as u8);
            }
        }
        out
    }

    /// Encode and append k-1 zero flush bits, returning (coded bits,
    /// total input length including flush). Flushing forces the trellis
    /// back to state 0, which the decoder exploits (known end state).
    pub fn encode_flushed(&mut self, bits: &[u8]) -> (Vec<u8>, usize) {
        let flush = vec![0u8; (self.code.k() - 1) as usize];
        let mut all = self.encode(bits);
        all.extend(self.encode(&flush));
        (all, bits.len() + flush.len())
    }

    /// Encode into packed words (the paper's §III input compaction).
    pub fn encode_packed(&mut self, bits: &[u8]) -> BitVec {
        BitVec::from_bits(&self.encode(bits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ccsds() -> Code {
        Code::from_octal(7, &["171", "133"]).unwrap()
    }

    #[test]
    fn matches_python_mirror() {
        // same vector as the python sanity check:
        // encode([1,0,1,1,0,0,0,0,0,0]) -> first 12 coded bits
        let mut e = Encoder::new(ccsds());
        let out = e.encode(&[1, 0, 1, 1, 0, 0, 0, 0, 0, 0]);
        assert_eq!(&out[..12], &[1, 1, 1, 0, 0, 0, 1, 0, 0, 1, 0, 1]);
        assert_eq!(e.state(), 0);
    }

    #[test]
    fn zero_input_zero_output() {
        let mut e = Encoder::new(ccsds());
        assert!(e.encode(&[0; 20]).iter().all(|&b| b == 0));
    }

    #[test]
    fn flush_returns_to_zero() {
        let mut e = Encoder::new(ccsds());
        let (_, n) = e.encode_flushed(&[1, 1, 0, 1, 0, 1, 1]);
        assert_eq!(e.state(), 0);
        assert_eq!(n, 7 + 6);
    }

    #[test]
    fn output_length_is_beta_per_bit() {
        let mut e = Encoder::new(ccsds());
        assert_eq!(e.encode(&[1, 0, 1]).len(), 6);
    }

    #[test]
    fn state_evolution_is_shift_register() {
        let mut e = Encoder::new(ccsds());
        e.push(1);
        assert_eq!(e.state(), 0b100000);
        e.push(1);
        assert_eq!(e.state(), 0b110000);
        e.push(0);
        assert_eq!(e.state(), 0b011000);
    }
}
