//! Trellis structure: butterfly (§IV, Thm 1-2) and radix-2^rho dragonfly
//! (§VI, Thm 3-5) index math, super-branches (§VII, Thm 6-7) and the
//! precomputed tables the decoders use.

use anyhow::{bail, Result};

use super::poly::Code;

/// All permutations of 0..n (n is at most 2^rho = 4 here).
pub fn permutations(n: usize) -> Vec<Vec<u32>> {
    fn build(remaining: &mut Vec<u32>, cur: &mut Vec<u32>, all: &mut Vec<Vec<u32>>) {
        if remaining.is_empty() {
            all.push(cur.clone());
            return;
        }
        for idx in 0..remaining.len() {
            let v = remaining.remove(idx);
            cur.push(v);
            build(remaining, cur, all);
            cur.pop();
            remaining.insert(idx, v);
        }
    }
    let mut all = Vec::new();
    build(&mut (0..n as u32).collect(), &mut Vec::new(), &mut all);
    all
}

/// The paper's `x_{hi:lo}` bit-field operator (Eq 23): bits [lo, hi).
#[inline]
pub fn bits_field(x: u32, hi: u32, lo: u32) -> u32 {
    if hi <= lo {
        0
    } else {
        (x >> lo) & ((1u32 << (hi - lo)) - 1)
    }
}

/// Precomputed trellis tables for one code.
#[derive(Clone, Debug)]
pub struct Trellis {
    code: Code,
    /// `next[state][u]` — successor state.
    pub next: Vec<[u32; 2]>,
    /// `out[state][u]` — beta-bit branch output.
    pub out: Vec<[u32; 2]>,
    /// `prev[state]` — the two predecessors (low index first).
    pub prev: Vec<[u32; 2]>,
}

impl Trellis {
    pub fn new(code: Code) -> Self {
        let s = code.n_states();
        let mut next = vec![[0u32; 2]; s];
        let mut out = vec![[0u32; 2]; s];
        let mut prev = vec![[0u32; 2]; s];
        for i in 0..s as u32 {
            for u in 0..2u32 {
                next[i as usize][u as usize] = code.next_state(i, u);
                out[i as usize][u as usize] = code.branch_output(i, u);
            }
        }
        for j in 0..s as u32 {
            let (p0, p1) = code.prev_states(j);
            prev[j as usize] = [p0, p1];
        }
        Trellis { code, next, out, prev }
    }

    pub fn code(&self) -> &Code {
        &self.code
    }

    // --- dragonfly index math (Thm 4) -----------------------------------

    pub fn n_dragonflies(&self, rho: u32) -> usize {
        1 << (self.code.k() - 1 - rho)
    }

    /// Thm 4: global state for (dragonfly f, local stage x, local state y):
    /// pre-bubble + bubble + post-bubble.
    pub fn dragonfly_state(&self, rho: u32, f: u32, x: u32, y: u32) -> u32 {
        let k = self.code.k();
        debug_assert!(x <= rho && y < (1 << rho) && (f as usize) < self.n_dragonflies(rho));
        let pre = bits_field(y, rho, rho - x) << (k - x - 1);
        let bub = f << (rho - x);
        let post = bits_field(y, rho - x, 0);
        pre + bub + post
    }

    /// Decompose a global *right* state: (dragonfly f, local right state).
    #[inline]
    pub fn right_local(&self, rho: u32, s: u32) -> (u32, u32) {
        let ndf = self.n_dragonflies(rho) as u32;
        (s % ndf, s / ndf)
    }

    /// The unique super-branch path (Thm 6) from left local y_left to
    /// right local y_right of dragonfly f: rho (global_state, input,
    /// branch_output) steps. Input consumed at local step x is bit x of
    /// y_right.
    pub fn superbranch_path(&self, rho: u32, f: u32, y_left: u32, y_right: u32)
                            -> Vec<(u32, u32, u32)> {
        let mut steps = Vec::with_capacity(rho as usize);
        let mut y = y_left;
        for x in 0..rho {
            let u = (y_right >> x) & 1;
            let s = self.dragonfly_state(rho, f, x, y);
            steps.push((s, u, self.code.branch_output(s, u)));
            y = (u << (rho - 1)) | (y >> 1);
        }
        debug_assert_eq!(y, y_right);
        steps
    }

    /// rho*beta-bit super-branch output; step x occupies bits
    /// [x*beta, (x+1)*beta) — the Eq 33 L-vector layout.
    pub fn superbranch_output(&self, rho: u32, f: u32, y_left: u32, y_right: u32) -> u32 {
        let beta = self.code.beta() as u32;
        let mut out = 0u32;
        for (x, (_, _, o)) in self.superbranch_path(rho, f, y_left, y_right).iter().enumerate() {
            out |= o << (x as u32 * beta);
        }
        out
    }

    /// Per-(i,j) super-branch outputs flattened in P_j-block order —
    /// equal signatures mean equal Theta-hat matrices.
    pub fn theta_signature(&self, rho: u32, f: u32) -> Vec<u32> {
        let n = 1u32 << rho;
        let mut sig = Vec::with_capacity((n * n) as usize);
        for j in 0..n {
            for i in 0..n {
                sig.push(self.superbranch_output(rho, f, i, j));
            }
        }
        sig
    }

    /// Search the left-state permutation pi with
    /// `alpha_f[i -> j] == alpha_r[pi(i) -> j]` for all i, j (§VIII-D).
    pub fn find_left_permutation(&self, rho: u32, f: u32, r: u32) -> Option<Vec<u32>> {
        let n = (1u32 << rho) as usize;
        let sig_f = self.theta_signature(rho, f); // index [j*n + i]
        let sig_r = self.theta_signature(rho, r);
        for cand in permutations(n) {
            let ok = (0..n).all(|j| {
                (0..n).all(|i| sig_f[j * n + i] == sig_r[j * n + cand[i] as usize])
            });
            if ok {
                return Some(cand);
            }
        }
        None
    }

    /// Dragonfly groups (paper Fig 10/11): returns (reps, group_of, perm)
    /// where `theta_f[i] == theta_rep[perm_f[i]]`.
    pub fn dragonfly_groups(&self, rho: u32) -> (Vec<u32>, Vec<u32>, Vec<Vec<u32>>) {
        let nf = self.n_dragonflies(rho) as u32;
        let mut reps: Vec<u32> = Vec::new();
        let mut group_of = vec![0u32; nf as usize];
        let mut perm: Vec<Vec<u32>> = vec![Vec::new(); nf as usize];
        for f in 0..nf {
            let mut found = false;
            for (gid, &r) in reps.iter().enumerate() {
                if let Some(pi) = self.find_left_permutation(rho, f, r) {
                    group_of[f as usize] = gid as u32;
                    perm[f as usize] = pi;
                    found = true;
                    break;
                }
            }
            if !found {
                group_of[f as usize] = reps.len() as u32;
                perm[f as usize] = (0..(1 << rho)).collect();
                reps.push(f);
            }
        }
        (reps, group_of, perm)
    }

    /// Validate the code is usable with the radix-4 scheme (n divisible
    /// constraints etc). Returns rho-compatible status.
    pub fn supports_radix(&self, rho: u32) -> Result<()> {
        if rho == 0 || rho >= self.code.k() {
            bail!("radix-2^{rho} invalid for k={}", self.code.k());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trellis() -> Trellis {
        Trellis::new(Code::from_octal(7, &["171", "133"]).unwrap())
    }

    #[test]
    fn thm1_butterfly_indices() {
        let t = trellis();
        for f in 0..t.n_dragonflies(1) as u32 {
            assert_eq!(t.dragonfly_state(1, f, 0, 0), 2 * f); // i0
            assert_eq!(t.dragonfly_state(1, f, 0, 1), 2 * f + 1); // i1
            assert_eq!(t.dragonfly_state(1, f, 1, 0), f); // j0
            assert_eq!(t.dragonfly_state(1, f, 1, 1), f + 32); // j1
        }
    }

    #[test]
    fn eq28_radix4_indices() {
        let t = trellis();
        let f = 3;
        // Eq 28: i_y = 4f+y; m: 2f, 2f+1, 2f+32, 2f+33; j_y = f + y*16
        for y in 0..4 {
            assert_eq!(t.dragonfly_state(2, f, 0, y), 4 * f + y);
            assert_eq!(t.dragonfly_state(2, f, 2, y), f + y * 16);
        }
        assert_eq!(t.dragonfly_state(2, f, 1, 0), 2 * f);
        assert_eq!(t.dragonfly_state(2, f, 1, 1), 2 * f + 1);
        assert_eq!(t.dragonfly_state(2, f, 1, 2), 2 * f + 32);
        assert_eq!(t.dragonfly_state(2, f, 1, 3), 2 * f + 33);
    }

    #[test]
    fn thm3_dragonflies_are_isolated() {
        // every branch from a left state of dragonfly f lands on a middle
        // state of the same dragonfly, etc.
        let t = trellis();
        for rho in 1..=3u32 {
            for f in 0..t.n_dragonflies(rho) as u32 {
                for x in 0..rho {
                    for y in 0..(1u32 << rho) {
                        let s = t.dragonfly_state(rho, f, x, y);
                        for u in 0..2u32 {
                            let nxt = t.next[s as usize][u as usize];
                            // nxt must be some local state of the same dragonfly at x+1
                            let found = (0..(1u32 << rho))
                                .any(|y2| t.dragonfly_state(rho, f, x + 1, y2) == nxt);
                            assert!(found, "rho={rho} f={f} x={x} y={y} u={u}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn thm6_unique_paths() {
        let t = trellis();
        for f in 0..16u32 {
            for i in 0..4u32 {
                for j in 0..4u32 {
                    let path = t.superbranch_path(2, f, i, j);
                    assert_eq!(path.len(), 2);
                    // consecutive: next(state_0, u_0) == state_1
                    let (s0, u0, _) = path[0];
                    let (s1, _, _) = path[1];
                    assert_eq!(t.next[s0 as usize][u0 as usize], s1);
                }
            }
        }
    }

    #[test]
    fn fig10_dragonfly_groups() {
        let t = trellis();
        let (reps, group_of, perm) = t.dragonfly_groups(2);
        assert_eq!(reps, vec![0, 1, 4, 5]);
        // Eq 39-42: DG0={0,2,8,10} DG1={1,3,9,11} DG2={4,6,12,14} DG3={5,7,13,15}
        assert_eq!(
            group_of,
            vec![0, 1, 0, 1, 2, 3, 2, 3, 0, 1, 0, 1, 2, 3, 2, 3]
        );
        // permutation property holds
        for f in 0..16u32 {
            let r = reps[group_of[f as usize] as usize];
            let pi = &perm[f as usize];
            for j in 0..4 {
                for i in 0..4usize {
                    assert_eq!(
                        t.superbranch_output(2, f, i as u32, j),
                        t.superbranch_output(2, r, pi[i], j)
                    );
                }
            }
        }
    }

    #[test]
    fn thm2_butterfly_outputs_related() {
        // Cor 2.1: for polys with MSB=LSB=1, outer branches share output,
        // inner branches are the toggled version.
        let t = trellis();
        let beta_mask = 0b11;
        for f in 0..32u32 {
            let o00 = t.superbranch_output(1, f, 0, 0);
            let o11 = t.superbranch_output(1, f, 1, 1);
            let o01 = t.superbranch_output(1, f, 0, 1);
            let o10 = t.superbranch_output(1, f, 1, 0);
            assert_eq!(o00, o11);
            assert_eq!(o01, o10);
            assert_eq!(o00 ^ beta_mask, o01);
        }
    }

    #[test]
    fn superbranch_input_bits() {
        let t = trellis();
        // walking the path consumes bit x of y_right at step x
        for f in 0..16u32 {
            for j in 0..4u32 {
                let path = t.superbranch_path(2, f, 1, j);
                assert_eq!(path[0].1, j & 1);
                assert_eq!(path[1].1, (j >> 1) & 1);
            }
        }
    }
}
