//! Convolutional coding substrate: generator polynomials, the encoder
//! FSM, trellis/butterfly/dragonfly index math (paper §II, §IV, §VI-VII)
//! and the tensor packing specs (§V, §VIII). Bit-for-bit mirror of
//! `python/compile/trellis.py` + `packing.py`.

pub mod poly;
pub mod encoder;
pub mod trellis;
pub mod packing;
pub mod puncture;
pub mod registry;

pub use encoder::Encoder;
pub use poly::Code;
pub use trellis::Trellis;
