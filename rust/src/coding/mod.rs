//! Convolutional coding substrate: generator polynomials, the encoder
//! FSM, trellis/butterfly/dragonfly index math (paper §II, §IV, §VI-VII)
//! and the tensor packing specs (§V, §VIII). Bit-for-bit mirror of
//! `python/compile/trellis.py` + `packing.py`.

pub mod poly;
pub mod encoder;
pub mod trellis;
pub mod packing;
pub mod puncture;
pub mod registry;

pub use encoder::Encoder;
pub use poly::Code;
pub use trellis::Trellis;

/// How a convolutional block is terminated — the workload axis that
/// decides what the decoder may assume about the trellis ends
/// (`docs/DECODING-MODES.md` is the full guide).
///
/// * [`Flushed`](TerminationMode::Flushed) — `k - 1` zero bits are
///   appended so the encoder returns to state 0; the decoder pins both
///   ends of the stream. Costs `(k - 1) / (n + k - 1)` of the rate.
/// * [`TailBiting`](TerminationMode::TailBiting) — the shift register
///   is pre-loaded with the last `k - 1` data bits so the start state
///   equals the end state (LTE PBCH/PDCCH style); no flush bits, no
///   rate loss. The decoder extends every frame *circularly* instead of
///   pinning states.
/// * [`Truncated`](TerminationMode::Truncated) — the block simply stops;
///   no flush bits, but the last bits get weaker protection (the
///   decoder starts traceback from the best-metric end state).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TerminationMode {
    /// Zero-flush to state 0 at the block end (the classic default).
    #[default]
    Flushed,
    /// Circular block: start state == end state, no flush bits.
    TailBiting,
    /// Direct truncation: no flush bits, unanchored end state.
    Truncated,
}

impl TerminationMode {
    /// The CLI / TOML names, in declaration order (`--termination`).
    pub const NAMES: &'static [&'static str] = &["flushed", "tail-biting", "truncated"];

    /// Canonical CLI/TOML name of this mode.
    pub const fn as_str(self) -> &'static str {
        match self {
            TerminationMode::Flushed => "flushed",
            TerminationMode::TailBiting => "tail-biting",
            TerminationMode::Truncated => "truncated",
        }
    }

    /// Parse a CLI/TOML name (`tail_biting`/`tailbiting` aliases accepted).
    pub fn parse(name: &str) -> Option<TerminationMode> {
        match name {
            "flushed" => Some(TerminationMode::Flushed),
            "tail-biting" | "tail_biting" | "tailbiting" => Some(TerminationMode::TailBiting),
            "truncated" => Some(TerminationMode::Truncated),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) with the canonical typed error — the one
    /// parse-failure message shared by the builder and the CLI.
    pub fn parse_named(name: &str) -> crate::error::Result<TerminationMode> {
        TerminationMode::parse(name).ok_or_else(|| {
            crate::error::Error::config(format!(
                "unknown termination {name:?}; known: {}",
                TerminationMode::NAMES.join(" ")
            ))
        })
    }

    /// Trellis stages appended beyond the data bits (`k - 1` flush
    /// stages for [`Flushed`](TerminationMode::Flushed), 0 otherwise) —
    /// the per-block rate overhead this mode pays.
    pub fn flush_stages(self, k: u32) -> usize {
        match self {
            TerminationMode::Flushed => (k - 1) as usize,
            TerminationMode::TailBiting | TerminationMode::Truncated => 0,
        }
    }
}

impl std::fmt::Display for TerminationMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::TerminationMode;

    #[test]
    fn termination_names_roundtrip() {
        for &name in TerminationMode::NAMES {
            let mode = TerminationMode::parse(name).expect(name);
            assert_eq!(mode.as_str(), name);
        }
        assert_eq!(TerminationMode::parse("tail_biting"), Some(TerminationMode::TailBiting));
        assert_eq!(TerminationMode::parse("nope"), None);
        assert_eq!(TerminationMode::default(), TerminationMode::Flushed);
        let e = TerminationMode::parse_named("nope").unwrap_err();
        assert!(e.to_string().contains("known: flushed tail-biting truncated"), "{e}");
    }

    #[test]
    fn flush_stages_only_for_flushed() {
        assert_eq!(TerminationMode::Flushed.flush_stages(7), 6);
        assert_eq!(TerminationMode::TailBiting.flush_stages(7), 0);
        assert_eq!(TerminationMode::Truncated.flush_stages(7), 0);
    }
}
