//! Puncturing: rate adaptation for the industrial protocols the paper's
//! introduction motivates (DVB-T/S, WiFi, WiMAX all derive rates 2/3,
//! 3/4, 5/6, 7/8 from the same (2,1,7) 171/133 mother code by deleting
//! coded bits on a periodic pattern).
//!
//! The decoder side *depunctures* by re-inserting zero LLRs (= erasures:
//! no information, Eq 2 contributes 0 to every branch metric), so the
//! same Viterbi machinery decodes every derived rate.

use anyhow::{bail, Result};

/// A puncturing pattern over the mother-code output stream.
///
/// `keep[i]` says whether coded bit `i mod keep.len()` is transmitted.
/// Patterns are beta-aligned: `keep.len()` must be a multiple of beta.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Puncturer {
    keep: Vec<bool>,
    beta: usize,
}

impl Puncturer {
    pub fn new(keep: Vec<bool>, beta: usize) -> Result<Puncturer> {
        if keep.is_empty() || keep.len() % beta != 0 {
            bail!("pattern length {} must be a positive multiple of beta {beta}", keep.len());
        }
        if !keep.iter().any(|&k| k) {
            bail!("pattern deletes every bit");
        }
        // every information stage must keep at least one coded bit overall
        // (otherwise the trellis has unconstrained stages at high rates) —
        // we only *warn*-by-construction: standard patterns all satisfy it.
        Ok(Puncturer { keep, beta })
    }

    /// Standard DVB-T / IEEE 802.11 patterns for the (2,1,7) mother code.
    /// `name` is "1/2", "2/3", "3/4", "5/6" or "7/8".
    pub fn standard(name: &str) -> Result<Puncturer> {
        // patterns in (X1 Y1 X2 Y2 ...) order, X = poly 171, Y = poly 133
        let keep: Vec<bool> = match name {
            "1/2" => vec![true, true],
            "2/3" => vec![true, true, false, true],
            "3/4" => vec![true, true, false, true, true, false],
            "5/6" => vec![true, true, false, true, true, false, false, true, true, false],
            "7/8" => vec![
                true, true, false, true, false, true, false, true, true, false,
                false, true, true, false,
            ],
            _ => bail!("unknown standard rate {name:?} (know 1/2, 2/3, 3/4, 5/6, 7/8)"),
        };
        Puncturer::new(keep, 2)
    }

    pub fn pattern_len(&self) -> usize {
        self.keep.len()
    }

    /// Effective code rate: info bits per transmitted bit.
    pub fn rate(&self) -> f64 {
        let kept = self.keep.iter().filter(|&&k| k).count();
        (self.keep.len() / self.beta) as f64 / kept as f64
    }

    /// Drop punctured positions from a coded bit stream.
    pub fn puncture(&self, coded: &[u8]) -> Vec<u8> {
        coded
            .iter()
            .enumerate()
            .filter(|(i, _)| self.keep[i % self.keep.len()])
            .map(|(_, &b)| b)
            .collect()
    }

    /// Number of transmitted bits for `n` mother-coded bits.
    pub fn punctured_len(&self, n: usize) -> usize {
        let full = n / self.keep.len();
        let kept_per = self.keep.iter().filter(|&&k| k).count();
        let mut len = full * kept_per;
        for i in 0..(n % self.keep.len()) {
            len += usize::from(self.keep[i]);
        }
        len
    }

    /// Re-insert erasures (0.0 LLR) at punctured positions, restoring the
    /// mother-code stream the decoder expects. `n_coded` is the mother
    /// stream length (stages * beta).
    pub fn depuncture(&self, llr: &[f32], n_coded: usize) -> Result<Vec<f32>> {
        if llr.len() != self.punctured_len(n_coded) {
            bail!("llr length {} does not match punctured length {} for {n_coded} coded bits",
                  llr.len(), self.punctured_len(n_coded));
        }
        let mut out = vec![0f32; n_coded];
        let mut src = 0usize;
        for (i, slot) in out.iter_mut().enumerate() {
            if self.keep[i % self.keep.len()] {
                *slot = llr[src];
                src += 1;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{awgn::AwgnChannel, bpsk};
    use crate::coding::{registry, trellis::Trellis, Encoder};
    use crate::viterbi::scalar;

    #[test]
    fn standard_rates() {
        for (name, rate) in [("1/2", 0.5), ("2/3", 2.0 / 3.0), ("3/4", 0.75),
                             ("5/6", 5.0 / 6.0), ("7/8", 7.0 / 8.0)] {
            let p = Puncturer::standard(name).unwrap();
            assert!((p.rate() - rate).abs() < 1e-12, "{name}");
        }
        assert!(Puncturer::standard("9/10").is_err());
    }

    #[test]
    fn puncture_depuncture_roundtrip_positions() {
        let p = Puncturer::standard("3/4").unwrap();
        let coded: Vec<u8> = (0..24).map(|i| (i % 2) as u8).collect();
        let tx = p.puncture(&coded);
        assert_eq!(tx.len(), p.punctured_len(24));
        let llr: Vec<f32> = tx.iter().map(|&b| 1.0 - 2.0 * b as f32).collect();
        let dep = p.depuncture(&llr, 24).unwrap();
        // kept positions carry the symbol, punctured are 0 (erasure)
        let mut kept = 0;
        for (i, &v) in dep.iter().enumerate() {
            if p.keep[i % p.pattern_len()] {
                assert_eq!(v, 1.0 - 2.0 * coded[i] as f32);
                kept += 1;
            } else {
                assert_eq!(v, 0.0);
            }
        }
        assert_eq!(kept, tx.len());
    }

    #[test]
    fn rejects_bad_patterns() {
        assert!(Puncturer::new(vec![], 2).is_err());
        assert!(Puncturer::new(vec![true], 2).is_err());
        assert!(Puncturer::new(vec![false, false], 2).is_err());
    }

    #[test]
    fn rate_three_quarters_decodes_clean_at_high_snr() {
        let code = registry::paper_code();
        let t = Trellis::new(code.clone());
        let p = Puncturer::standard("3/4").unwrap();
        let mut enc = Encoder::new(code.clone());
        let mut bits = crate::util::rng::Rng::new(3).bits(300);
        bits.extend_from_slice(&[0; 6]);
        let coded = enc.encode(&bits);
        let tx_bits = p.puncture(&coded);
        let tx = bpsk::modulate(&tx_bits);
        let mut ch = AwgnChannel::new(7.0, p.rate(), 5);
        let rx = ch.transmit(&tx);
        let llr_p: Vec<f32> = rx.iter().map(|&x| x as f32).collect();
        let llr = p.depuncture(&llr_p, coded.len()).unwrap();
        let lam0 = scalar::initial_metrics(64, Some(0));
        let out = scalar::decode(&t, &llr, &lam0, Some(0));
        assert_eq!(out, bits, "rate-3/4 punctured decode at 7 dB");
    }

    #[test]
    fn punctured_len_handles_partial_periods() {
        let p = Puncturer::standard("2/3").unwrap(); // keep 3 of 4
        assert_eq!(p.punctured_len(4), 3);
        assert_eq!(p.punctured_len(6), 5); // 4 -> 3, then T,T of next period
        assert_eq!(p.punctured_len(0), 0);
    }

    #[test]
    fn depuncture_length_mismatch_errors() {
        let p = Puncturer::standard("2/3").unwrap();
        assert!(p.depuncture(&[0.0; 5], 4).is_err());
    }
}
