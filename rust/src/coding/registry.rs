//! Registry of standard convolutional codes (the industrial protocols the
//! paper's introduction motivates: DVB-T/S, GPRS, GSM, LTE, 3G/CDMA,
//! WiFi, WiMAX).

use anyhow::{bail, Result};

use super::poly::Code;

/// A named standard code.
pub struct StandardCode {
    pub name: &'static str,
    pub description: &'static str,
    pub k: u32,
    pub polys_octal: &'static [&'static str],
}

/// All registered standard codes.
pub const STANDARD_CODES: &[StandardCode] = &[
    StandardCode {
        name: "ccsds",
        description: "(2,1,7) 171/133 — CCSDS, DVB-T/S, IEEE 802.11, the paper's §IX code",
        k: 7,
        polys_octal: &["171", "133"],
    },
    StandardCode {
        name: "gsm",
        description: "(2,1,5) 23/33 — GSM TCH/FS",
        k: 5,
        polys_octal: &["23", "33"],
    },
    StandardCode {
        name: "lte",
        description: "(3,1,7) 133/171/165 — LTE / CDMA tail-biting family (rate 1/3)",
        k: 7,
        polys_octal: &["133", "171", "165"],
    },
    StandardCode {
        name: "wimax",
        description: "(2,1,7) 171/133 — IEEE 802.16 (same polys as CCSDS)",
        k: 7,
        polys_octal: &["171", "133"],
    },
    StandardCode {
        name: "dab",
        description: "(4,1,7) 133/171/145/133 — ETSI DAB rate-1/4 mother code",
        k: 7,
        polys_octal: &["133", "171", "145", "133"],
    },
];

/// Look up a standard code by name (case-insensitive).
pub fn lookup(name: &str) -> Result<Code> {
    let lname = name.to_ascii_lowercase();
    for sc in STANDARD_CODES {
        if sc.name == lname {
            return Code::from_octal(sc.k, sc.polys_octal);
        }
    }
    bail!(
        "unknown code {name:?}; known: {}",
        STANDARD_CODES.iter().map(|c| c.name).collect::<Vec<_>>().join(", ")
    )
}

/// The paper's evaluation code: (2,1,7), polynomials 171/133 octal.
pub fn paper_code() -> Code {
    Code::from_octal(7, &["171", "133"]).expect("static code is valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_known_codes() {
        for sc in STANDARD_CODES {
            let c = lookup(sc.name).unwrap();
            assert_eq!(c.k(), sc.k);
            assert_eq!(c.beta(), sc.polys_octal.len());
        }
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(lookup("CCSDS").is_ok());
    }

    #[test]
    fn lookup_unknown_fails() {
        assert!(lookup("nope").is_err());
    }

    #[test]
    fn paper_code_matches_fig1() {
        let c = paper_code();
        assert_eq!(c.polys(), &[0o171, 0o133]);
        assert_eq!(c.n_states(), 64);
    }
}
