//! Code definition: constraint length k and generator polynomials.
//!
//! Conventions (identical to `python/compile/trellis.py`):
//! * state = previous k-1 input bits, newest at MSB;
//! * next state on input u: `(u << (k-2)) | (state >> 1)`;
//! * polynomial MSB multiplies the current input bit (paper Eq 1);
//! * branch output bit b = parity of `poly[b] & ((u << (k-1)) | state)`.

use anyhow::{bail, Result};

/// A rate-1/beta convolutional code (beta, 1, k).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Code {
    k: u32,
    polys: Vec<u32>,
}

impl Code {
    pub fn new(k: u32, polys: Vec<u32>) -> Result<Code> {
        if k < 3 || k > 16 {
            bail!("constraint length k={k} out of supported range [3,16]");
        }
        if polys.len() < 2 {
            bail!("need beta >= 2 generator polynomials, got {}", polys.len());
        }
        for &g in &polys {
            if g == 0 || g >= (1 << k) {
                bail!("polynomial {g:o} (octal) out of range for k={k}");
            }
        }
        Ok(Code { k, polys })
    }

    /// Parse octal polynomial strings, e.g. `Code::from_octal(7, &["171","133"])`.
    pub fn from_octal(k: u32, octal: &[&str]) -> Result<Code> {
        let polys = octal
            .iter()
            .map(|s| u32::from_str_radix(s, 8).map_err(Into::into))
            .collect::<Result<Vec<_>>>()?;
        Code::new(k, polys)
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn beta(&self) -> usize {
        self.polys.len()
    }

    pub fn polys(&self) -> &[u32] {
        &self.polys
    }

    pub fn n_states(&self) -> usize {
        1 << (self.k - 1)
    }

    /// Code rate 1/beta.
    pub fn rate(&self) -> f64 {
        1.0 / self.beta() as f64
    }

    // --- FSM -----------------------------------------------------------

    #[inline]
    pub fn next_state(&self, state: u32, u: u32) -> u32 {
        (u << (self.k - 2)) | (state >> 1)
    }

    /// beta-bit branch output; bit b corresponds to polynomial b.
    #[inline]
    pub fn branch_output(&self, state: u32, u: u32) -> u32 {
        let reg = (u << (self.k - 1)) | state;
        let mut out = 0u32;
        for (b, &g) in self.polys.iter().enumerate() {
            out |= (((g & reg).count_ones() & 1) as u32) << b;
        }
        out
    }

    /// The two predecessor states of j (paper prv(j)), low index first.
    #[inline]
    pub fn prev_states(&self, j: u32) -> (u32, u32) {
        let base = (j << 1) & (self.n_states() as u32 - 1);
        (base, base | 1)
    }

    /// alpha_in of any branch into j (the MSB of j).
    #[inline]
    pub fn branch_input(&self, j: u32) -> u32 {
        j >> (self.k - 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ccsds() -> Code {
        Code::from_octal(7, &["171", "133"]).unwrap()
    }

    #[test]
    fn octal_parsing() {
        let c = ccsds();
        assert_eq!(c.polys(), &[0o171, 0o133]);
        assert_eq!(c.k(), 7);
        assert_eq!(c.beta(), 2);
        assert_eq!(c.n_states(), 64);
        assert_eq!(c.rate(), 0.5);
    }

    #[test]
    fn rejects_bad_codes() {
        assert!(Code::new(2, vec![1, 2]).is_err());
        assert!(Code::new(7, vec![0o171]).is_err());
        assert!(Code::new(7, vec![0, 0o133]).is_err());
        assert!(Code::new(7, vec![1 << 7, 0o133]).is_err());
    }

    #[test]
    fn fsm_transitions() {
        let c = ccsds();
        // from state 0, input 1 -> state 2^(k-2) = 32
        assert_eq!(c.next_state(0, 1), 32);
        assert_eq!(c.next_state(0, 0), 0);
        // shifting: state 0b100000, input 0 -> 0b010000
        assert_eq!(c.next_state(32, 0), 16);
    }

    #[test]
    fn prev_states_invert_next() {
        let c = ccsds();
        for i in 0..c.n_states() as u32 {
            for u in 0..2 {
                let j = c.next_state(i, u);
                let (p0, p1) = c.prev_states(j);
                assert!(i == p0 || i == p1, "state {i} not a predecessor of {j}");
                assert_eq!(c.branch_input(j), u);
            }
        }
    }

    #[test]
    fn branch_output_known_value() {
        let c = ccsds();
        // all-zero register -> all-zero output; all-ones -> parity of polys
        assert_eq!(c.branch_output(0, 0), 0);
        let all = c.branch_output((1 << 6) - 1, 1);
        let expect = ((0o171u32.count_ones() & 1) | ((0o133u32.count_ones() & 1) << 1)) as u32;
        assert_eq!(all, expect);
    }
}
